//! Experiment configuration files: INI-style `[section] key = value`
//! (see `rust/src/util/ini.rs` — the toml crate is unavailable offline,
//! and the subset used here parses identically). Example:
//!
//! ```ini
//! topology = "mi300x"
//!
//! [attention]
//! batch = 2
//! h_q = 64
//! h_k = 8
//! n_ctx = 8192
//! d_head = 128
//!
//! [sim]
//! policy = "shf"
//! generations = 2
//! ```
//!
//! The full key set (attention blocks/causal/dtype, sim kernel selection
//! incl. `kernel = "decode"` + `num_splits`, engine knobs, and the
//! `[serve]` decode-serving-loop section) is documented in
//! `examples/experiment.ini` and mirrored by [`ATTENTION_KEYS`] /
//! [`SIM_KEYS`] / [`SERVE_KEYS`] (plus [`CLUSTER_KEYS`] and
//! [`DISAGG_KEYS`] for the deployment sections, [`TUNE_KEYS`] for
//! the mapping autotuner, and [`TRACE_KEYS`] / [`FAULTS_KEYS`] for
//! load-replay traces and cluster fault plans); the
//! `example_experiment_file_stays_reconciled` test pins that the example
//! file and this parser stay reconciled, and
//! `example_serve_file_builds_the_serving_config` pins the worked
//! serving scenario in `examples/serve.ini` (docs/SERVING.md).

use crate::attn::{AttnConfig, KernelKind};
use crate::cluster::{ClusterTopology, ShardPlan, ShardStrategy};
use crate::mapping::Policy;
use crate::sim::SimConfig;
use crate::topology::{presets, Topology};
use crate::util::ini::Ini;

/// Every `[attention]` key [`ExperimentConfig::parse`] reads. Update
/// this list (and `examples/experiment.ini`) when adding a key — the
/// `example_experiment_file_stays_reconciled` test checks the example
/// file against it.
pub const ATTENTION_KEYS: [&str; 9] = [
    "batch", "h_q", "h_k", "n_ctx", "d_head", "block_m", "block_n", "causal", "dtype_bytes",
];

/// Every `[sim]` key [`ExperimentConfig::parse`] reads (see
/// [`ATTENTION_KEYS`]).
pub const SIM_KEYS: [&str; 10] = [
    "policy", "kernel", "num_splits", "backward", "generations", "jitter_denom",
    "launch_stagger", "prefetch_depth", "compute_efficiency", "seed",
];

/// Every `[serve]` key [`ExperimentConfig::parse`] reads — the decode
/// serving loop's knobs (`numa-attn serve --config`, docs/SERVING.md).
/// The served model geometry comes from `[attention]` (`n_ctx` is the
/// KV capacity; `batch` is ignored — the per-step batch is the number of
/// active sessions). `chunk_tokens`/`step_token_budget` switch on
/// chunked prefill with mixed prefill+decode steps (docs/SERVING.md §6;
/// both default to 0 = the historical monolithic behavior).
/// `kv_block_tokens`/`prefix_share_pct`/`kv_capacity_mb` configure the
/// paged KV pool with cross-session prefix sharing (docs/KVCACHE.md;
/// the pool engages only when both block size and share rate are > 0).
pub const SERVE_KEYS: [&str; 13] = [
    "arrival_per_sec", "prefill_lengths", "decode_tokens", "sessions", "max_active", "steps",
    "kv_bucket", "chunk_tokens", "step_token_budget", "kv_block_tokens", "prefix_share_pct",
    "kv_capacity_mb", "seed",
];

/// Every `[cluster]` key [`ExperimentConfig::parse`] reads — the
/// two-level NUMA cluster deployment (`numa-attn cluster --config`,
/// docs/CLUSTER.md). The worked key set lives in `examples/cluster.ini`,
/// pinned by the `example_cluster_file_stays_reconciled` test.
pub const CLUSTER_KEYS: [&str; 6] =
    ["devices", "topology", "tp", "strategy", "link_gbs", "link_latency_us"];

/// Every `[disagg]` key [`ExperimentConfig::parse`] reads — the
/// disaggregated prefill/decode deployment (`numa-attn disagg --config`,
/// docs/DISAGG.md). Pool sizes, the KV-handoff interconnect, and the
/// SLO mix; the serving trace itself comes from `[serve]` and the model
/// geometry from `[attention]`. The worked key set lives in
/// `examples/disagg.ini`, pinned by the
/// `example_disagg_file_stays_reconciled` test.
pub const DISAGG_KEYS: [&str; 6] = [
    "prefill_devices", "decode_devices", "link_gbs", "link_latency_us", "interactive_pct",
    "ttft_slo_ms",
];

/// Every `[tune]` key [`ExperimentConfig::parse`] reads — the mapping
/// autotuner's search strategy (`numa-attn tune --config`,
/// docs/TUNING.md). The workload itself comes from `[attention]` +
/// `[sim]` (kernel selection incl. `kernel = "decode"` + `num_splits`).
/// The worked key set lives in `examples/tune.ini`, pinned by the
/// `example_tune_file_stays_reconciled` test.
pub const TUNE_KEYS: [&str; 2] = ["search", "beam_width"];

/// Every `[trace]` key [`ExperimentConfig::parse`] reads — the
/// load-replay trace the serving loops draw sessions from instead of
/// the stationary `[serve]` generator (docs/SERVING.md §8). Either
/// `file` (an explicit `.trace` schedule the CLI loads) or the
/// [`crate::workload::TraceSpec`] generator keys, never both. The
/// worked key set lives in `examples/serve_burst.ini`, pinned by the
/// `example_serve_burst_file_stays_reconciled` test.
pub const TRACE_KEYS: [&str; 13] = [
    "file", "shape", "seed", "sessions", "base_per_sec", "peak_per_sec", "period_sec", "duty_pct",
    "prefill_lengths", "decode_tokens", "share_pct", "share_span", "interactive_pct",
];

/// Every `[faults]` key [`ExperimentConfig::parse`] reads — the
/// cluster fault-injection plan (`numa-attn cluster --faults`,
/// docs/SERVING.md §9). Either an explicit `events` schedule or the
/// seeded-plan keys (`seed`/`count`/`horizon_sec`), never both. The
/// worked key set lives in `examples/faults.ini`, pinned by the
/// `example_faults_file_stays_reconciled` test.
pub const FAULTS_KEYS: [&str; 4] = ["events", "seed", "count", "horizon_sec"];

/// Top-level experiment file.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Topology preset name.
    pub topology: String,
    /// `[attention]` section (required).
    pub attention: AttentionSection,
    /// `[sim]` section (optional keys).
    pub sim: SimSection,
    /// `[serve]` section (decode serving loop; every key optional).
    pub serve: ServeSection,
    /// `[cluster]` section (`None` when the file has no such section).
    pub cluster: Option<ClusterSection>,
    /// `[disagg]` section (`None` when the file has no such section).
    pub disagg: Option<DisaggSection>,
    /// `[tune]` section (`None` when the file has no such section).
    pub tune: Option<TuneSection>,
    /// `[trace]` section (`None` when the file has no such section).
    pub trace: Option<TraceSection>,
    /// `[faults]` section (`None` when the file has no such section).
    pub faults: Option<FaultsSection>,
}

/// `[attention]` section: the workload geometry.
#[derive(Debug, Clone)]
pub struct AttentionSection {
    /// Batch size Z.
    pub batch: usize,
    /// Query heads.
    pub h_q: usize,
    /// KV heads (defaults to `h_q`, i.e. MHA).
    pub h_k: Option<usize>,
    /// Context length.
    pub n_ctx: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Q row-block size (default 128).
    pub block_m: usize,
    /// K/V column-block size (default 64).
    pub block_n: usize,
    /// Causal masking (default false).
    pub causal: bool,
    /// Bytes per element (default 2 = bf16/fp16).
    pub dtype_bytes: usize,
}

/// `[sim]` section: engine knobs (every key optional).
#[derive(Debug, Clone, Default)]
pub struct SimSection {
    /// Policy short/full name; omitted = compare all four.
    pub policy: Option<String>,
    /// Legacy alias for `kernel = "backward"`.
    pub backward: bool,
    /// Which pass to run: "forward" (default), "backward", or "decode".
    pub kernel: Option<String>,
    /// KV splits per (batch, head); required when `kernel = "decode"`.
    pub num_splits: Option<usize>,
    /// Steady-state sample generations; omitted = run the whole grid.
    pub generations: Option<usize>,
    /// 1-in-N per-step jitter (see [`SimConfig::jitter_denom`]).
    pub jitter_denom: Option<u64>,
    /// Launch stagger cap (see [`SimConfig::launch_stagger`]).
    pub launch_stagger: Option<u64>,
    /// Double-buffered prefetch depth.
    pub prefetch_depth: Option<u32>,
    /// Fraction of peak CU FLOPs the inner GEMMs achieve.
    pub compute_efficiency: Option<f64>,
    /// Jitter/stagger hash seed.
    pub seed: Option<u64>,
}

/// `[serve]` section: the decode serving loop's traffic trace and loop
/// knobs (every key optional; defaults from
/// [`crate::coordinator::ServeConfig`]).
#[derive(Debug, Clone, Default)]
pub struct ServeSection {
    /// Session arrival rate (sessions per simulated second).
    pub arrival_per_sec: Option<f64>,
    /// Comma-separated prompt-length mix, e.g. `"2048,8192"`.
    pub prefill_lengths: Option<String>,
    /// Comma-separated decode-budget mix, e.g. `"32,128"`.
    pub decode_tokens: Option<String>,
    /// Sessions in the trace.
    pub sessions: Option<usize>,
    /// Max concurrently decoding sessions (continuous-batch cap).
    pub max_active: Option<usize>,
    /// Decode-step budget.
    pub steps: Option<usize>,
    /// KV bucketing quantum (tokens).
    pub kv_bucket: Option<usize>,
    /// Chunked-prefill chunk size in prompt tokens (0 = off).
    pub chunk_tokens: Option<usize>,
    /// Mixed-step token budget, decode tokens first (0 = uncapped).
    pub step_token_budget: Option<usize>,
    /// Paged KV block size in prompt tokens (0 = pool off).
    pub kv_block_tokens: Option<usize>,
    /// Percent of sessions opening with the shared prefix (0 = off).
    pub prefix_share_pct: Option<f64>,
    /// Paged-pool byte budget in MiB (0 = unlimited).
    pub kv_capacity_mb: Option<usize>,
    /// Trace seed.
    pub seed: Option<u64>,
}

/// `[cluster]` section: the two-level NUMA deployment — device count,
/// per-device topology, tensor-parallel head sharding, and the
/// interconnect model (docs/CLUSTER.md).
#[derive(Debug, Clone, Default)]
pub struct ClusterSection {
    /// Devices in the cluster (required).
    pub devices: Option<usize>,
    /// Per-device topology preset (default: the top-level `topology`).
    pub topology: Option<String>,
    /// Tensor-parallel degree (default: `devices`; must equal it —
    /// shards map 1:1 onto devices).
    pub tp: Option<usize>,
    /// Shard layout: `"contiguous"` (default) or `"strided"`.
    pub strategy: Option<String>,
    /// Per-device interconnect bandwidth in GB/s (default 128).
    pub link_gbs: Option<f64>,
    /// Interconnect hop latency in microseconds (default 1).
    pub link_latency_us: Option<f64>,
}

/// `[disagg]` section: the disaggregated prefill/decode deployment —
/// pool sizes, the KV-handoff interconnect, and the SLO traffic mix
/// (docs/DISAGG.md). The trace and loop knobs come from `[serve]`.
#[derive(Debug, Clone, Default)]
pub struct DisaggSection {
    /// Devices in the prefill pool (0 = colocated, no handoff).
    pub prefill_devices: Option<usize>,
    /// Devices in the decode pool (default 1).
    pub decode_devices: Option<usize>,
    /// KV-handoff interconnect bandwidth in GB/s (default 128).
    pub link_gbs: Option<f64>,
    /// KV-handoff hop latency in microseconds (default 1).
    pub link_latency_us: Option<f64>,
    /// Percent of sessions in the interactive SLO class (default 30).
    pub interactive_pct: Option<f64>,
    /// Interactive TTFT target in milliseconds (0 = preemption off).
    pub ttft_slo_ms: Option<f64>,
}

/// `[tune]` section: the mapping autotuner's search strategy over the
/// composed mapping algebra (docs/TUNING.md). The tuned workload comes
/// from `[attention]` + `[sim]`.
#[derive(Debug, Clone, Default)]
pub struct TuneSection {
    /// Search strategy: `"exhaustive"` (default) or `"beam"`.
    pub search: Option<String>,
    /// Legacy-plane survivors a beam search expands (default 2;
    /// only meaningful with `search = "beam"`).
    pub beam_width: Option<usize>,
}

/// `[trace]` section: a load-replay trace for the serving loops
/// (docs/SERVING.md §8) — either an explicit `.trace` file or a seeded
/// bursty/diurnal generator ([`crate::workload::TraceSpec`]). When
/// present, the serving trace comes from here instead of the
/// stationary `[serve]` generator; the `[serve]` loop knobs
/// (`max_active`, `steps`, chunking, the KV pool) still apply.
#[derive(Debug, Clone, Default)]
pub struct TraceSection {
    /// Path to an explicit `.trace` schedule. The CLI loads and parses
    /// it (this module never touches the filesystem); contradictory
    /// with the generator keys below.
    pub file: Option<String>,
    /// Arrival-rate curve: `"bursty"` (default) or `"diurnal"`.
    pub shape: Option<String>,
    /// Generator seed.
    pub seed: Option<u64>,
    /// Sessions to emit.
    pub sessions: Option<usize>,
    /// Off-burst / trough arrival rate (sessions per second).
    pub base_per_sec: Option<f64>,
    /// Burst / crest arrival rate (sessions per second).
    pub peak_per_sec: Option<f64>,
    /// Length of one rate cycle in seconds.
    pub period_sec: Option<f64>,
    /// Leading percentage of each period at the peak rate (bursty).
    pub duty_pct: Option<f64>,
    /// Comma-separated prompt-length mix.
    pub prefill_lengths: Option<String>,
    /// Comma-separated decode-budget mix.
    pub decode_tokens: Option<String>,
    /// Percentage of sessions on the canonical shared prefix.
    pub share_pct: Option<f64>,
    /// Shared-prefix span in tokens (clamped to the prompt).
    pub share_span: Option<usize>,
    /// Percentage of sessions in the interactive SLO class.
    pub interactive_pct: Option<f64>,
}

/// `[faults]` section: the cluster fault-injection plan
/// (docs/SERVING.md §9) — either an explicit `events` schedule or a
/// seeded plan ([`crate::coordinator::FaultSpec`]). Applies to
/// `numa-attn cluster`; an absent section (or an all-default one)
/// injects nothing and reproduces the historical cluster output
/// byte-for-byte.
#[derive(Debug, Clone, Default)]
pub struct FaultsSection {
    /// Explicit schedule, `device:fail_sec:recover_sec` comma-separated;
    /// contradictory with `count`.
    pub events: Option<String>,
    /// Seed for a generated plan.
    pub seed: Option<u64>,
    /// Outages to generate (0 = none).
    pub count: Option<usize>,
    /// Serve horizon the generated outages are spread across (seconds).
    pub horizon_sec: Option<f64>,
}

/// Which pass an experiment file requests ([`ExperimentConfig::kernel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpKernel {
    /// The FA2 forward kernel.
    Forward,
    /// The combined backward pass (dK/dV + dQ).
    Backward,
    /// The two-phase split-KV decode pass, with this many KV splits.
    Decode(usize),
}

impl ExperimentConfig {
    /// Parse an experiment file (INI subset of TOML; see the module doc
    /// and `examples/experiment.ini`).
    pub fn parse(text: &str) -> Result<Self, String> {
        let ini = Ini::parse(text)?;
        if !ini.has_section("attention") {
            return Err("missing [attention] section".into());
        }
        let attention = AttentionSection {
            batch: ini
                .get_parsed("attention", "batch")?
                .ok_or("attention.batch required")?,
            h_q: ini
                .get_parsed("attention", "h_q")?
                .ok_or("attention.h_q required")?,
            h_k: ini.get_parsed("attention", "h_k")?,
            n_ctx: ini
                .get_parsed("attention", "n_ctx")?
                .ok_or("attention.n_ctx required")?,
            d_head: ini
                .get_parsed("attention", "d_head")?
                .ok_or("attention.d_head required")?,
            block_m: ini.get_parsed("attention", "block_m")?.unwrap_or(128),
            block_n: ini.get_parsed("attention", "block_n")?.unwrap_or(64),
            causal: ini.get_parsed("attention", "causal")?.unwrap_or(false),
            dtype_bytes: ini.get_parsed("attention", "dtype_bytes")?.unwrap_or(2),
        };
        let sim = SimSection {
            policy: ini.get("sim", "policy").map(|s| s.to_string()),
            backward: ini.get_parsed("sim", "backward")?.unwrap_or(false),
            kernel: ini.get("sim", "kernel").map(|s| s.to_string()),
            num_splits: ini.get_parsed("sim", "num_splits")?,
            generations: ini.get_parsed("sim", "generations")?,
            jitter_denom: ini.get_parsed("sim", "jitter_denom")?,
            launch_stagger: ini.get_parsed("sim", "launch_stagger")?,
            prefetch_depth: ini.get_parsed("sim", "prefetch_depth")?,
            compute_efficiency: ini.get_parsed("sim", "compute_efficiency")?,
            seed: ini.get_parsed("sim", "seed")?,
        };
        let serve = ServeSection {
            arrival_per_sec: ini.get_parsed("serve", "arrival_per_sec")?,
            prefill_lengths: ini.get("serve", "prefill_lengths").map(|s| s.to_string()),
            decode_tokens: ini.get("serve", "decode_tokens").map(|s| s.to_string()),
            sessions: ini.get_parsed("serve", "sessions")?,
            max_active: ini.get_parsed("serve", "max_active")?,
            steps: ini.get_parsed("serve", "steps")?,
            kv_bucket: ini.get_parsed("serve", "kv_bucket")?,
            chunk_tokens: ini.get_parsed("serve", "chunk_tokens")?,
            step_token_budget: ini.get_parsed("serve", "step_token_budget")?,
            kv_block_tokens: ini.get_parsed("serve", "kv_block_tokens")?,
            prefix_share_pct: ini.get_parsed("serve", "prefix_share_pct")?,
            kv_capacity_mb: ini.get_parsed("serve", "kv_capacity_mb")?,
            seed: ini.get_parsed("serve", "seed")?,
        };
        let cluster = if ini.has_section("cluster") {
            Some(ClusterSection {
                devices: ini.get_parsed("cluster", "devices")?,
                topology: ini.get("cluster", "topology").map(|s| s.to_string()),
                tp: ini.get_parsed("cluster", "tp")?,
                strategy: ini.get("cluster", "strategy").map(|s| s.to_string()),
                link_gbs: ini.get_parsed("cluster", "link_gbs")?,
                link_latency_us: ini.get_parsed("cluster", "link_latency_us")?,
            })
        } else {
            None
        };
        let disagg = if ini.has_section("disagg") {
            Some(DisaggSection {
                prefill_devices: ini.get_parsed("disagg", "prefill_devices")?,
                decode_devices: ini.get_parsed("disagg", "decode_devices")?,
                link_gbs: ini.get_parsed("disagg", "link_gbs")?,
                link_latency_us: ini.get_parsed("disagg", "link_latency_us")?,
                interactive_pct: ini.get_parsed("disagg", "interactive_pct")?,
                ttft_slo_ms: ini.get_parsed("disagg", "ttft_slo_ms")?,
            })
        } else {
            None
        };
        let tune = if ini.has_section("tune") {
            Some(TuneSection {
                search: ini.get("tune", "search").map(|s| s.to_string()),
                beam_width: ini.get_parsed("tune", "beam_width")?,
            })
        } else {
            None
        };
        let trace = if ini.has_section("trace") {
            Some(TraceSection {
                file: ini.get("trace", "file").map(|s| s.to_string()),
                shape: ini.get("trace", "shape").map(|s| s.to_string()),
                seed: ini.get_parsed("trace", "seed")?,
                sessions: ini.get_parsed("trace", "sessions")?,
                base_per_sec: ini.get_parsed("trace", "base_per_sec")?,
                peak_per_sec: ini.get_parsed("trace", "peak_per_sec")?,
                period_sec: ini.get_parsed("trace", "period_sec")?,
                duty_pct: ini.get_parsed("trace", "duty_pct")?,
                prefill_lengths: ini.get("trace", "prefill_lengths").map(|s| s.to_string()),
                decode_tokens: ini.get("trace", "decode_tokens").map(|s| s.to_string()),
                share_pct: ini.get_parsed("trace", "share_pct")?,
                share_span: ini.get_parsed("trace", "share_span")?,
                interactive_pct: ini.get_parsed("trace", "interactive_pct")?,
            })
        } else {
            None
        };
        let faults = if ini.has_section("faults") {
            Some(FaultsSection {
                events: ini.get("faults", "events").map(|s| s.to_string()),
                seed: ini.get_parsed("faults", "seed")?,
                count: ini.get_parsed("faults", "count")?,
                horizon_sec: ini.get_parsed("faults", "horizon_sec")?,
            })
        } else {
            None
        };
        Ok(ExperimentConfig {
            topology: ini.get("", "topology").unwrap_or("mi300x").to_string(),
            attention,
            sim,
            serve,
            cluster,
            disagg,
            tune,
            trace,
            faults,
        })
    }

    /// Resolve the topology preset named by the file. An unknown name
    /// reports the available preset list
    /// ([`presets::by_name_or_err`]).
    pub fn topology(&self) -> Result<Topology, String> {
        presets::by_name_or_err(&self.topology)
    }

    /// Build the cluster topology from `[cluster]`: `devices` copies of
    /// the per-device preset (default: the top-level `topology`) joined
    /// by the configured interconnect. Requires a `[cluster]` section
    /// with `devices`, and `tp` (when given) equal to `devices`.
    pub fn cluster_topology(&self) -> Result<ClusterTopology, String> {
        let c = self.cluster.as_ref().ok_or("missing [cluster] section")?;
        let devices = c.devices.ok_or("cluster.devices required")?;
        if devices == 0 {
            return Err("cluster.devices must be > 0".into());
        }
        cluster_tp(c)?;
        let device = presets::by_name_or_err(c.topology.as_deref().unwrap_or(&self.topology))?;
        let link_gbs = c.link_gbs.unwrap_or(crate::cluster::DEFAULT_LINK_BYTES_PER_SEC / 1e9);
        let link_latency_us =
            c.link_latency_us.unwrap_or(crate::cluster::DEFAULT_LINK_LATENCY_SEC * 1e6);
        let cluster =
            ClusterTopology::homogeneous(&device, devices, link_gbs * 1e9, link_latency_us * 1e-6);
        cluster.validate()?;
        Ok(cluster)
    }

    /// Build the shard plan from `[cluster]` + `[attention]`: the
    /// GQA-aware tensor-parallel partition of the served model's heads
    /// at the configured degree and strategy. Enforces the same
    /// `tp == devices` consistency rule as [`Self::cluster_topology`],
    /// so an inconsistent section errors here instead of panicking later
    /// in the executor.
    pub fn shard_plan(&self) -> Result<ShardPlan, String> {
        let c = self.cluster.as_ref().ok_or("missing [cluster] section")?;
        let tp = cluster_tp(c)?;
        let strategy = match c.strategy.as_deref() {
            None => ShardStrategy::Contiguous,
            Some(s) => s.parse::<ShardStrategy>()?,
        };
        ShardPlan::new(&self.attn()?, tp, strategy)
    }

    /// Build and validate the attention config from `[attention]`.
    pub fn attn(&self) -> Result<AttnConfig, String> {
        let a = &self.attention;
        let cfg = AttnConfig {
            batch: a.batch,
            h_q: a.h_q,
            h_k: a.h_k.unwrap_or(a.h_q),
            n_ctx: a.n_ctx,
            d_head: a.d_head,
            block_m: a.block_m,
            block_n: a.block_n,
            causal: a.causal,
            dtype_bytes: a.dtype_bytes,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Which pass the file requests: the `sim.kernel` key, with the
    /// legacy `sim.backward` flag as an alias for `kernel = "backward"`.
    pub fn kernel(&self) -> Result<ExpKernel, String> {
        let s = &self.sim;
        match s.kernel.as_deref() {
            None => Ok(if s.backward { ExpKernel::Backward } else { ExpKernel::Forward }),
            Some("forward") => Ok(ExpKernel::Forward),
            Some("backward") => Ok(ExpKernel::Backward),
            Some("decode") => {
                let ns = s
                    .num_splits
                    .ok_or("sim.num_splits required when sim.kernel = \"decode\"")?;
                if ns == 0 {
                    return Err("sim.num_splits must be >= 1".into());
                }
                Ok(ExpKernel::Decode(ns))
            }
            Some(other) => Err(format!(
                "unknown sim.kernel '{other}' (expected forward, backward, or decode)"
            )),
        }
    }

    /// Build the sim config for one policy: kernel selection from
    /// [`Self::kernel`], sampling from `generations`, then the knob
    /// overrides.
    pub fn sim(&self, policy: Policy) -> Result<SimConfig, String> {
        let topo = self.topology()?;
        let s = &self.sim;
        let mut cfg = match s.generations {
            Some(g) => SimConfig::sampled(policy, &topo, g),
            None => SimConfig::forward(policy),
        };
        match self.kernel()? {
            ExpKernel::Forward => {}
            ExpKernel::Backward => {
                cfg.kernel = KernelKind::BwdDkDv;
                cfg.compute_overhead = SimConfig::backward(policy).compute_overhead;
            }
            ExpKernel::Decode(num_splits) => {
                // Decode grids are small: run them exactly, like
                // `SimConfig::decode`. An oversized split count clamps
                // to the shared bound so it can't schedule empty splits.
                let num_splits = self.attn()?.clamp_num_splits(num_splits);
                cfg.kernel = KernelKind::DecodeSplitKv { num_splits };
                cfg.max_wg_completions = 0;
                cfg.warmup_completions = 0;
            }
        }
        if let Some(j) = s.jitter_denom {
            cfg.jitter_denom = j;
        }
        if let Some(ls) = s.launch_stagger {
            cfg.launch_stagger = ls;
        }
        if let Some(p) = s.prefetch_depth {
            cfg.prefetch_depth = p;
        }
        if let Some(e) = s.compute_efficiency {
            cfg.compute_efficiency = e;
        }
        if let Some(seed) = s.seed {
            cfg.seed = seed;
        }
        Ok(cfg)
    }

    /// Policy list: explicit one, or all four.
    pub fn policies(&self) -> Result<Vec<Policy>, String> {
        match &self.sim.policy {
            Some(p) => Ok(vec![p.parse()?]),
            None => Ok(crate::mapping::ALL_POLICIES.to_vec()),
        }
    }

    /// Build the decode serving loop configuration: model geometry from
    /// `[attention]` (`n_ctx` = KV capacity, `batch` ignored), traffic
    /// and loop knobs from `[serve]` with
    /// [`crate::coordinator::ServeConfig`] defaults for absent keys.
    pub fn serve_config(&self) -> Result<crate::coordinator::ServeConfig, String> {
        let attn = self.attn()?;
        let s = &self.serve;
        let defaults = crate::coordinator::ServeConfig::default();
        let cfg = crate::coordinator::ServeConfig {
            h_q: attn.h_q,
            h_k: attn.h_k,
            d_head: attn.d_head,
            block_m: attn.block_m,
            block_n: attn.block_n,
            causal: attn.causal,
            dtype_bytes: attn.dtype_bytes,
            kv_cap: attn.n_ctx,
            kv_bucket: s.kv_bucket.unwrap_or(defaults.kv_bucket),
            arrival_per_sec: s.arrival_per_sec.unwrap_or(defaults.arrival_per_sec),
            prefill_lengths: match &s.prefill_lengths {
                Some(list) => parse_usize_list("serve.prefill_lengths", list)?,
                None => defaults.prefill_lengths,
            },
            decode_tokens: match &s.decode_tokens {
                Some(list) => parse_usize_list("serve.decode_tokens", list)?,
                None => defaults.decode_tokens,
            },
            sessions: s.sessions.unwrap_or(defaults.sessions),
            max_active: s.max_active.unwrap_or(defaults.max_active),
            max_steps: s.steps.unwrap_or(defaults.max_steps),
            chunk_tokens: s.chunk_tokens.unwrap_or(defaults.chunk_tokens),
            step_token_budget: s.step_token_budget.unwrap_or(defaults.step_token_budget),
            kv_block_tokens: s.kv_block_tokens.unwrap_or(defaults.kv_block_tokens),
            prefix_share_pct: s.prefix_share_pct.unwrap_or(defaults.prefix_share_pct),
            kv_capacity_mb: s.kv_capacity_mb.unwrap_or(defaults.kv_capacity_mb),
            seed: s.seed.unwrap_or(defaults.seed),
            trace: self.trace_spec()?.map(|spec| spec.generate()),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The explicit `.trace` schedule `[trace] file` names, when the
    /// section replays a file. This module never touches the
    /// filesystem — the CLI loads the file and installs the parsed
    /// [`crate::workload::TraceReplay`] on the serving config itself.
    pub fn trace_file(&self) -> Option<&str> {
        self.trace.as_ref()?.file.as_deref()
    }

    /// Build and validate the generated-trace spec from `[trace]`
    /// (docs/SERVING.md §8): `None` when the file has no such section
    /// or when it replays an explicit file instead
    /// ([`Self::trace_file`]). Every parameter is checked here, at
    /// parse time, so a bad INI value reports an actionable `[trace]`
    /// error instead of panicking inside the generator.
    pub fn trace_spec(&self) -> Result<Option<crate::workload::TraceSpec>, String> {
        let Some(t) = &self.trace else { return Ok(None) };
        if t.file.is_some() {
            if t.shape.is_some()
                || t.seed.is_some()
                || t.sessions.is_some()
                || t.base_per_sec.is_some()
                || t.peak_per_sec.is_some()
                || t.period_sec.is_some()
                || t.duty_pct.is_some()
                || t.prefill_lengths.is_some()
                || t.decode_tokens.is_some()
                || t.share_pct.is_some()
                || t.share_span.is_some()
                || t.interactive_pct.is_some()
            {
                return Err("[trace] file replays an explicit schedule: the generator keys \
                     are contradictory — drop them or the file key"
                    .into());
            }
            return Ok(None);
        }
        let defaults = crate::workload::TraceSpec::default();
        let spec = crate::workload::TraceSpec {
            shape: match t.shape.as_deref() {
                Some(s) => crate::workload::TraceShape::from_name(s)?,
                None => defaults.shape,
            },
            seed: t.seed.unwrap_or(defaults.seed),
            sessions: t.sessions.unwrap_or(defaults.sessions),
            base_per_sec: t.base_per_sec.unwrap_or(defaults.base_per_sec),
            peak_per_sec: t.peak_per_sec.unwrap_or(defaults.peak_per_sec),
            period_sec: t.period_sec.unwrap_or(defaults.period_sec),
            duty_pct: t.duty_pct.unwrap_or(defaults.duty_pct),
            prefill_lengths: match &t.prefill_lengths {
                Some(list) => parse_usize_list("trace.prefill_lengths", list)?,
                None => defaults.prefill_lengths,
            },
            decode_tokens: match &t.decode_tokens {
                Some(list) => parse_usize_list("trace.decode_tokens", list)?,
                None => defaults.decode_tokens,
            },
            share_pct: t.share_pct.unwrap_or(defaults.share_pct),
            share_span: t.share_span.unwrap_or(defaults.share_span),
            interactive_pct: t.interactive_pct.unwrap_or(defaults.interactive_pct),
        };
        spec.validate()?;
        Ok(Some(spec))
    }

    /// Build the cluster fault-injection spec from `[faults]`
    /// (docs/SERVING.md §9): the all-default (inject-nothing) spec when
    /// the file has no such section. The explicit `events` schedule is
    /// format-checked here, at parse time, so a malformed INI value
    /// reports an actionable `[faults]` error up front; device-range
    /// checks need the cluster size and run when the spec resolves
    /// against it ([`crate::coordinator::FaultSpec::resolve`]).
    pub fn fault_spec(&self) -> Result<crate::coordinator::FaultSpec, String> {
        let defaults = crate::coordinator::FaultSpec::default();
        let Some(f) = &self.faults else { return Ok(defaults) };
        let spec = crate::coordinator::FaultSpec {
            events: f.events.clone().unwrap_or_default(),
            seed: f.seed.unwrap_or(defaults.seed),
            count: f.count.unwrap_or(defaults.count),
            horizon_sec: f.horizon_sec.unwrap_or(defaults.horizon_sec),
        };
        if !spec.events.is_empty() && spec.count > 0 {
            return Err("[faults] events and count are contradictory: an explicit schedule \
                 already fixes the plan — drop count or the events list"
                .into());
        }
        crate::coordinator::FaultPlan::parse(&spec.events)?;
        if spec.count > 0 && !(spec.horizon_sec > 0.0 && spec.horizon_sec.is_finite()) {
            return Err(format!(
                "[faults] horizon_sec must be > 0 to seed a plan, got {}",
                spec.horizon_sec
            ));
        }
        Ok(spec)
    }

    /// Build the disaggregated serving configuration: the serving loop
    /// from `[serve]`/`[attention]` via [`Self::serve_config`], pool
    /// sizes, the KV-handoff interconnect, and the SLO mix from
    /// `[disagg]` with [`crate::coordinator::DisaggConfig`] defaults for
    /// absent keys. Requires a `[disagg]` section (use
    /// [`Self::serve_config`] for the colocated single-pool loop).
    pub fn disagg_config(&self) -> Result<crate::coordinator::DisaggConfig, String> {
        let d = self.disagg.as_ref().ok_or("missing [disagg] section")?;
        let defaults = crate::coordinator::DisaggConfig::default();
        let cfg = crate::coordinator::DisaggConfig {
            serve: self.serve_config()?,
            prefill_devices: d.prefill_devices.unwrap_or(defaults.prefill_devices),
            decode_devices: d.decode_devices.unwrap_or(defaults.decode_devices),
            link_gbs: d.link_gbs.unwrap_or(defaults.link_gbs),
            link_latency_us: d.link_latency_us.unwrap_or(defaults.link_latency_us),
            interactive_pct: d.interactive_pct.unwrap_or(defaults.interactive_pct),
            ttft_slo_ms: d.ttft_slo_ms.unwrap_or(defaults.ttft_slo_ms),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The `[tune]` section's search strategy, mapped onto
    /// [`crate::coordinator::SearchMode`]: `None` when the file has no
    /// `[tune]` section (callers apply their own default), an error for
    /// an unknown strategy name, a zero beam width, or a contradictory
    /// `beam_width` on an exhaustive search.
    pub fn tune_mode(&self) -> Result<Option<crate::coordinator::SearchMode>, String> {
        let Some(t) = &self.tune else { return Ok(None) };
        match t.search.as_deref().unwrap_or("exhaustive") {
            "exhaustive" => {
                if t.beam_width.is_some() {
                    return Err("tune.beam_width without search = \"beam\" is contradictory: \
                         an exhaustive search prices every point"
                        .into());
                }
                Ok(Some(crate::coordinator::SearchMode::Exhaustive))
            }
            "beam" => {
                let width = t.beam_width.unwrap_or(2);
                if width == 0 {
                    return Err("tune.beam_width must be >= 1".into());
                }
                Ok(Some(crate::coordinator::SearchMode::Beam { width }))
            }
            other => {
                Err(format!("unknown tune.search '{other}' (expected exhaustive or beam)"))
            }
        }
    }
}

/// The `[cluster]` section's effective TP degree: `tp` defaulting to
/// `devices`, with the tp == devices consistency rule (shards map 1:1
/// onto devices) enforced in ONE place for both
/// [`ExperimentConfig::cluster_topology`] and
/// [`ExperimentConfig::shard_plan`].
fn cluster_tp(c: &ClusterSection) -> Result<usize, String> {
    match (c.devices, c.tp) {
        (Some(d), Some(t)) if t != d => Err(format!(
            "cluster.tp ({t}) must equal cluster.devices ({d}): \
             head shards map 1:1 onto devices"
        )),
        (_, Some(t)) => Ok(t),
        (Some(d), None) => Ok(d),
        (None, None) => Err("cluster.devices or cluster.tp required".into()),
    }
}

/// Parse a comma-separated list of positive integers (the `[serve]`
/// session-mix keys).
fn parse_usize_list(what: &str, list: &str) -> Result<Vec<usize>, String> {
    let out: Vec<usize> = list
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|e| format!("{what}: '{}': {e}", t.trim()))
        })
        .collect::<Result<_, _>>()?;
    if out.is_empty() || out.contains(&0) {
        return Err(format!("{what} must be a non-empty list of positive integers"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
topology = "mi300x"

[attention]
batch = 2
h_q = 64
h_k = 8
n_ctx = 8192
d_head = 128

[sim]
policy = "shf"
generations = 2
seed = 42
"#;

    #[test]
    fn parse_sample() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let topo = c.topology().unwrap();
        assert_eq!(topo.num_xcds, 8);
        let attn = c.attn().unwrap();
        assert_eq!(attn.h_k, 8);
        assert_eq!(attn.block_m, 128); // default
        let pols = c.policies().unwrap();
        assert_eq!(pols, vec![Policy::SwizzledHeadFirst]);
        let sim = c.sim(pols[0]).unwrap();
        assert_eq!(sim.seed, 42);
        assert!(sim.max_wg_completions > 0);
    }

    #[test]
    fn defaults_h_k_to_h_q() {
        let toml = r#"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64
"#;
        let c = ExperimentConfig::parse(toml).unwrap();
        assert_eq!(c.attn().unwrap().h_k, 8);
        assert_eq!(c.policies().unwrap().len(), 4);
    }

    #[test]
    fn decode_kernel_requires_num_splits() {
        let base = r#"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64
"#;
        let with_splits = format!("{base}\n[sim]\nkernel = \"decode\"\nnum_splits = 4\n");
        let c = ExperimentConfig::parse(&with_splits).unwrap();
        assert_eq!(c.kernel().unwrap(), ExpKernel::Decode(4));
        let sc = c.sim(Policy::SwizzledHeadFirst).unwrap();
        assert_eq!(sc.kernel, KernelKind::DecodeSplitKv { num_splits: 4 });
        assert_eq!(sc.max_wg_completions, 0, "decode runs exactly");

        // Oversized split counts clamp to one KV column block per split.
        let oversized = format!("{base}\n[sim]\nkernel = \"decode\"\nnum_splits = 512\n");
        let c = ExperimentConfig::parse(&oversized).unwrap();
        let sc = c.sim(Policy::NaiveHeadFirst).unwrap();
        let blocks = c.attn().unwrap().num_col_blocks();
        assert_eq!(sc.kernel, KernelKind::DecodeSplitKv { num_splits: blocks });

        let missing = format!("{base}\n[sim]\nkernel = \"decode\"\n");
        let c = ExperimentConfig::parse(&missing).unwrap();
        assert!(c.kernel().is_err());
        let zero = format!("{base}\n[sim]\nkernel = \"decode\"\nnum_splits = 0\n");
        assert!(ExperimentConfig::parse(&zero).unwrap().kernel().is_err());
        let bogus = format!("{base}\n[sim]\nkernel = \"prefill\"\n");
        assert!(ExperimentConfig::parse(&bogus).unwrap().kernel().is_err());
    }

    #[test]
    fn backward_flag_is_kernel_alias() {
        let text = r#"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64

[sim]
backward = true
"#;
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.kernel().unwrap(), ExpKernel::Backward);
        let sc = c.sim(Policy::NaiveHeadFirst).unwrap();
        assert_eq!(sc.kernel, KernelKind::BwdDkDv);
    }

    /// Extract the keys an example INI's reference block documents:
    /// `#   key ...` lines, skipping continuation lines and anything not
    /// shaped like a key identifier. Shared by both reconciliation tests
    /// so the comment convention is parsed exactly one way.
    fn documented_keys(text: &str) -> Vec<&str> {
        let mut keys = Vec::new();
        for line in text.lines() {
            // Reference-block entries look like `#   key ...`; prose,
            // section headers, and continuation lines don't match the
            // identifier shape.
            let Some(rest) = line.strip_prefix("#   ") else { continue };
            if rest.starts_with(' ') {
                continue; // continuation line, not a key entry
            }
            let Some(key) = rest.split_whitespace().next() else { continue };
            if key.is_empty() || !key.chars().all(|ch| ch.is_ascii_lowercase() || ch == '_') {
                continue;
            }
            keys.push(key);
        }
        keys
    }

    #[test]
    fn example_experiment_file_stays_reconciled() {
        // The reconciliation contract, enforced against the REAL example
        // file: it must parse, and every key its reference block
        // documents must be one this parser reads. A key added to the
        // docs without parser support (or vice versa) fails here.
        let text = include_str!("../../../examples/experiment.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        c.attn().unwrap();
        assert_eq!(c.policies().unwrap().len(), 4);
        let sc = c.sim(Policy::SwizzledHeadFirst).unwrap();
        assert!(sc.max_wg_completions > 0); // generations = 2 applied
        assert_eq!(sc.seed, 42);

        let documented = documented_keys(text);
        for key in &documented {
            assert!(
                *key == "topology"
                    || ATTENTION_KEYS.contains(key)
                    || SIM_KEYS.contains(key)
                    || SERVE_KEYS.contains(key),
                "examples/experiment.ini documents key '{key}' the parser does not read"
            );
        }
        // The reference block must actually cover the full key set.
        assert!(
            documented.len() >= 1 + ATTENTION_KEYS.len() + SIM_KEYS.len() + SERVE_KEYS.len(),
            "only {} keys documented in examples/experiment.ini",
            documented.len()
        );
    }

    #[test]
    fn example_serve_file_builds_the_serving_config() {
        // examples/serve.ini is the worked scenario docs/SERVING.md walks
        // through; this pins that it parses and every [serve] key lands.
        let text = include_str!("../../../examples/serve.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let cfg = c.serve_config().unwrap();
        assert_eq!((cfg.h_q, cfg.h_k, cfg.d_head), (64, 8, 128));
        assert_eq!(cfg.kv_cap, 131072);
        assert_eq!(cfg.kv_bucket, 4096);
        assert_eq!(cfg.arrival_per_sec, 80.0);
        assert_eq!(cfg.prefill_lengths, vec![2048, 8192]);
        assert_eq!(cfg.decode_tokens, vec![32, 128]);
        assert_eq!(cfg.sessions, 16);
        assert_eq!(cfg.max_active, 8);
        assert_eq!(cfg.max_steps, 1200);
        assert_eq!(cfg.chunk_tokens, 1024, "worked example serves chunked");
        assert_eq!(cfg.step_token_budget, 2048);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn example_serve_share_file_builds_the_pool_config() {
        // examples/serve_share.ini is the worked prefix-sharing scenario
        // docs/KVCACHE.md walks through (and the CI serve smoke runs);
        // this pins that it parses and the pool actually engages.
        let text = include_str!("../../../examples/serve_share.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let cfg = c.serve_config().unwrap();
        assert_eq!((cfg.h_q, cfg.h_k, cfg.d_head), (64, 8, 128));
        assert_eq!(cfg.kv_cap, 131072);
        assert_eq!(cfg.kv_block_tokens, 256);
        assert_eq!(cfg.prefix_share_pct, 80.0);
        assert_eq!(cfg.kv_capacity_mb, 1024);
        assert!(cfg.kv_pool_enabled(), "the worked example must exercise the pool");
        assert_eq!(cfg.chunk_tokens, 0, "monolithic admission: credits discount the charge");
        assert_eq!(cfg.shared_span(), 2048, "whole shortest prompt, block-aligned");
    }

    #[test]
    fn serve_chunk_keys_round_trip_and_reject_contradictions() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // Both keys land where documented.
        let on = format!("{base}\n[serve]\nchunk_tokens = 512\nstep_token_budget = 1024\n");
        let cfg = ExperimentConfig::parse(&on).unwrap().serve_config().unwrap();
        assert_eq!(cfg.chunk_tokens, 512);
        assert_eq!(cfg.step_token_budget, 1024);

        // Explicit zeros are the documented off state.
        let off = format!("{base}\n[serve]\nchunk_tokens = 0\nstep_token_budget = 0\n");
        let cfg = ExperimentConfig::parse(&off).unwrap().serve_config().unwrap();
        assert_eq!((cfg.chunk_tokens, cfg.step_token_budget), (0, 0));

        // A chunk that cannot fit in the step budget is rejected with an
        // actionable message naming both keys.
        let oversized = format!("{base}\n[serve]\nchunk_tokens = 2048\nstep_token_budget = 512\n");
        let err = ExperimentConfig::parse(&oversized).unwrap().serve_config().unwrap_err();
        assert!(err.contains("chunk_tokens (2048)"), "{err}");
        assert!(err.contains("step_token_budget (512)"), "{err}");

        // A budget with chunking off composes nothing: contradictory.
        let orphan = format!("{base}\n[serve]\nstep_token_budget = 1024\n");
        let err = ExperimentConfig::parse(&orphan).unwrap().serve_config().unwrap_err();
        assert!(err.contains("contradictory"), "{err}");

        // An uncapped budget with chunking on is valid.
        let uncapped = format!("{base}\n[serve]\nchunk_tokens = 512\n");
        let cfg = ExperimentConfig::parse(&uncapped).unwrap().serve_config().unwrap();
        assert_eq!((cfg.chunk_tokens, cfg.step_token_budget), (512, 0));
    }

    #[test]
    fn serve_kv_pool_keys_round_trip_and_validate() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // All three pool keys land where documented (docs/KVCACHE.md).
        let on = format!(
            "{base}\n[serve]\nkv_block_tokens = 256\nprefix_share_pct = 80\nkv_capacity_mb = 512\n"
        );
        let cfg = ExperimentConfig::parse(&on).unwrap().serve_config().unwrap();
        assert_eq!(cfg.kv_block_tokens, 256);
        assert_eq!(cfg.prefix_share_pct, 80.0);
        assert_eq!(cfg.kv_capacity_mb, 512);
        assert!(cfg.kv_pool_enabled());

        // Defaults: the pool is off.
        let cfg = ExperimentConfig::parse(base).unwrap().serve_config().unwrap();
        assert_eq!((cfg.kv_block_tokens, cfg.kv_capacity_mb), (0, 0));
        assert_eq!(cfg.prefix_share_pct, 0.0);
        assert!(!cfg.kv_pool_enabled());

        // A share rate outside [0, 100] is rejected.
        let over = format!("{base}\n[serve]\nkv_block_tokens = 256\nprefix_share_pct = 150\n");
        let err = ExperimentConfig::parse(&over).unwrap().serve_config().unwrap_err();
        assert!(err.contains("prefix_share_pct"), "{err}");
    }

    #[test]
    fn serve_section_defaults_and_list_errors() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // No [serve] section: the coordinator defaults apply, with the
        // geometry still taken from [attention].
        let c = ExperimentConfig::parse(base).unwrap();
        let cfg = c.serve_config().unwrap();
        let defaults = crate::coordinator::ServeConfig::default();
        assert_eq!(cfg.h_q, 16);
        assert_eq!(cfg.kv_cap, 8192);
        assert_eq!(cfg.max_active, defaults.max_active);
        assert_eq!(cfg.prefill_lengths, defaults.prefill_lengths);

        // Malformed list values are rejected with the key's name.
        let bad = format!("{base}\n[serve]\nprefill_lengths = \"2048,zebra\"\n");
        let err = ExperimentConfig::parse(&bad).unwrap().serve_config().unwrap_err();
        assert!(err.contains("prefill_lengths"), "{err}");
        let zero = format!("{base}\n[serve]\ndecode_tokens = \"0\"\n");
        assert!(ExperimentConfig::parse(&zero).unwrap().serve_config().is_err());
    }

    #[test]
    fn every_documented_key_is_parsed() {
        // An experiment file exercising EVERY supported key must parse,
        // and each value must land where the docs say (no
        // silently-ignored keys). The documented key set itself is
        // pinned by `example_experiment_file_stays_reconciled`.
        let text = r#"
topology = "quad_die"

[attention]
batch = 3
h_q = 16
h_k = 4
n_ctx = 4096
d_head = 64
block_m = 64
block_n = 32
causal = true
dtype_bytes = 4

[sim]
policy = "nhf"
kernel = "forward"
num_splits = 2
generations = 3
jitter_denom = 64
launch_stagger = 10
prefetch_depth = 1
compute_efficiency = 0.5
seed = 123
"#;
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "quad_die");
        let attn = c.attn().unwrap();
        assert_eq!(
            (attn.batch, attn.h_q, attn.h_k, attn.n_ctx, attn.d_head),
            (3, 16, 4, 4096, 64)
        );
        assert_eq!((attn.block_m, attn.block_n), (64, 32));
        assert!(attn.causal);
        assert_eq!(attn.dtype_bytes, 4);
        assert_eq!(c.policies().unwrap(), vec![Policy::NaiveHeadFirst]);
        assert_eq!(c.kernel().unwrap(), ExpKernel::Forward);
        assert_eq!(c.sim.num_splits, Some(2)); // parsed even when unused
        let sc = c.sim(Policy::NaiveHeadFirst).unwrap();
        assert!(sc.max_wg_completions > 0); // generations applied
        assert_eq!(sc.jitter_denom, 64);
        assert_eq!(sc.launch_stagger, 10);
        assert_eq!(sc.prefetch_depth, 1);
        assert_eq!(sc.compute_efficiency, 0.5);
        assert_eq!(sc.seed, 123);
    }

    #[test]
    fn rejects_bad_topo() {
        let toml = r#"
topology = "h100"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64
"#;
        let c = ExperimentConfig::parse(toml).unwrap();
        assert!(c.topology().is_err());
    }

    #[test]
    fn unknown_topology_error_lists_available_presets() {
        // The error must name every preset the user could have meant,
        // not just echo the bad name back.
        let toml = r#"
topology = "h100"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64
"#;
        let err = ExperimentConfig::parse(toml).unwrap().topology().unwrap_err();
        assert!(err.contains("'h100'"), "{err}");
        for name in crate::topology::presets::all_names() {
            assert!(err.contains(name), "error does not list preset '{name}': {err}");
        }
    }

    #[test]
    fn cluster_section_builds_topology_and_plan() {
        let text = r#"
topology = "mi300x"

[attention]
batch = 1
h_q = 64
h_k = 8
n_ctx = 65536
d_head = 128

[cluster]
devices = 4
topology = "quad_die"
tp = 4
strategy = "strided"
link_gbs = 200
link_latency_us = 2
"#;
        let c = ExperimentConfig::parse(text).unwrap();
        let cluster = c.cluster_topology().unwrap();
        assert_eq!(cluster.num_devices(), 4);
        assert_eq!(cluster.device(0).name, "quad_die", "per-device preset wins");
        assert_eq!(cluster.link_bytes_per_sec, 200e9);
        assert!((cluster.link_latency_sec - 2e-6).abs() < 1e-18);
        let plan = c.shard_plan().unwrap();
        assert_eq!(plan.tp, 4);
        assert_eq!(plan.strategy, crate::cluster::ShardStrategy::Strided);
        assert_eq!(plan.query_heads(0).len(), 16);
    }

    #[test]
    fn cluster_section_defaults_and_errors() {
        let base = r#"
topology = "mi300x"

[attention]
batch = 1
h_q = 64
h_k = 8
n_ctx = 65536
d_head = 128
"#;
        // No [cluster] section at all.
        let c = ExperimentConfig::parse(base).unwrap();
        assert!(c.cluster.is_none());
        assert!(c.cluster_topology().unwrap_err().contains("[cluster]"));

        // Minimal section: device preset defaults to the top level,
        // tp defaults to devices, interconnect to the module defaults.
        let minimal = format!("{base}\n[cluster]\ndevices = 8\n");
        let c = ExperimentConfig::parse(&minimal).unwrap();
        let cluster = c.cluster_topology().unwrap();
        assert_eq!(cluster.num_devices(), 8);
        assert_eq!(cluster.device(0).name, "mi300x");
        assert_eq!(cluster.link_bytes_per_sec, crate::cluster::DEFAULT_LINK_BYTES_PER_SEC);
        let plan = c.shard_plan().unwrap();
        assert_eq!(plan.tp, 8);
        assert_eq!(plan.strategy, crate::cluster::ShardStrategy::Contiguous);

        // devices is required; tp must equal devices; strategy must
        // parse; tp must divide the KV heads.
        let missing = format!("{base}\n[cluster]\ntp = 4\n");
        assert!(ExperimentConfig::parse(&missing).unwrap().cluster_topology().is_err());
        let mismatch = format!("{base}\n[cluster]\ndevices = 8\ntp = 4\n");
        let parsed = ExperimentConfig::parse(&mismatch).unwrap();
        let err = parsed.cluster_topology().unwrap_err();
        assert!(err.contains("must equal"), "{err}");
        // Both builders enforce the same rule: an inconsistent section
        // can never yield a plan that panics in the executor later.
        let err = parsed.shard_plan().unwrap_err();
        assert!(err.contains("must equal"), "{err}");
        let bogus = format!("{base}\n[cluster]\ndevices = 2\nstrategy = \"diagonal\"\n");
        assert!(ExperimentConfig::parse(&bogus).unwrap().shard_plan().is_err());
        let indivisible = format!("{base}\n[cluster]\ndevices = 3\n");
        let err = ExperimentConfig::parse(&indivisible).unwrap().shard_plan().unwrap_err();
        assert!(err.contains("never split"), "{err}");
        // Unknown per-device preset reports the available list.
        let badtopo = format!("{base}\n[cluster]\ndevices = 2\ntopology = \"b200\"\n");
        let err = ExperimentConfig::parse(&badtopo).unwrap().cluster_topology().unwrap_err();
        assert!(err.contains("available"), "{err}");
    }

    #[test]
    fn example_cluster_file_stays_reconciled() {
        // Same contract as `example_experiment_file_stays_reconciled`,
        // for the worked cluster scenario: the file must parse, build the
        // cluster topology + shard plan + serving config it documents,
        // and every key its reference block documents must be one the
        // parser reads — with the full [cluster] key set covered.
        let text = include_str!("../../../examples/cluster.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let cluster = c.cluster_topology().unwrap();
        assert_eq!(cluster.num_devices(), 8);
        let plan = c.shard_plan().unwrap();
        assert_eq!(plan.tp, 8);
        let serve = c.serve_config().unwrap();
        assert_eq!((serve.h_q, serve.h_k), (64, 8));
        // The plan must shard the served geometry cleanly.
        let local = plan.local_attn(&serve.base_geometry());
        assert_eq!((local.h_q, local.h_k), (8, 1));

        let documented = documented_keys(text);
        for key in &documented {
            assert!(
                *key == "topology"
                    || ATTENTION_KEYS.contains(key)
                    || SIM_KEYS.contains(key)
                    || SERVE_KEYS.contains(key)
                    || CLUSTER_KEYS.contains(key),
                "examples/cluster.ini documents key '{key}' the parser does not read"
            );
        }
        for key in CLUSTER_KEYS {
            assert!(
                documented.contains(&key),
                "examples/cluster.ini does not document the [cluster] key '{key}'"
            );
        }
    }

    #[test]
    fn disagg_section_round_trips_and_validates() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // No [disagg] section: building the disagg config errors, and
        // the colocated serve config is unaffected.
        let c = ExperimentConfig::parse(base).unwrap();
        assert!(c.disagg.is_none());
        assert!(c.disagg_config().unwrap_err().contains("[disagg]"));
        c.serve_config().unwrap();

        // Every documented key lands where docs/DISAGG.md says.
        let on = format!(
            "{base}\n[disagg]\nprefill_devices = 2\ndecode_devices = 4\nlink_gbs = 200\n\
             link_latency_us = 2\ninteractive_pct = 50\nttft_slo_ms = 25\n"
        );
        let cfg = ExperimentConfig::parse(&on).unwrap().disagg_config().unwrap();
        assert_eq!((cfg.prefill_devices, cfg.decode_devices), (2, 4));
        assert_eq!(cfg.link_gbs, 200.0);
        assert_eq!(cfg.link_latency_us, 2.0);
        assert_eq!(cfg.interactive_pct, 50.0);
        assert_eq!(cfg.ttft_slo_ms, 25.0);
        assert!(!cfg.colocated());
        assert_eq!(cfg.serve.h_q, 16, "geometry still comes from [attention]");

        // Minimal section: the coordinator defaults apply.
        let minimal = format!("{base}\n[disagg]\nprefill_devices = 1\n");
        let cfg = ExperimentConfig::parse(&minimal).unwrap().disagg_config().unwrap();
        let defaults = crate::coordinator::DisaggConfig::default();
        assert_eq!(cfg.decode_devices, defaults.decode_devices);
        assert_eq!(cfg.interactive_pct, defaults.interactive_pct);
        assert_eq!(cfg.link_gbs, defaults.link_gbs);

        // Degenerate sections are rejected with actionable messages.
        let zero = format!("{base}\n[disagg]\ndecode_devices = 0\n");
        assert!(ExperimentConfig::parse(&zero).unwrap().disagg_config().is_err());
        let indivisible = format!("{base}\n[disagg]\nprefill_devices = 3\n");
        let err = ExperimentConfig::parse(&indivisible).unwrap().disagg_config().unwrap_err();
        assert!(err.contains("must divide h_k"), "{err}");
        let badpct = format!("{base}\n[disagg]\ninteractive_pct = 150\n");
        let err = ExperimentConfig::parse(&badpct).unwrap().disagg_config().unwrap_err();
        assert!(err.contains("interactive_pct"), "{err}");
    }

    #[test]
    fn example_disagg_file_stays_reconciled() {
        // Same contract as `example_cluster_file_stays_reconciled`, for
        // the worked disaggregated scenario (docs/DISAGG.md): the file
        // must parse, build the disagg config it documents, and every
        // key its reference block documents must be one the parser reads
        // — with the full [disagg] key set covered.
        let text = include_str!("../../../examples/disagg.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let cfg = c.disagg_config().unwrap();
        assert_eq!((cfg.prefill_devices, cfg.decode_devices), (1, 1));
        assert_eq!(cfg.link_gbs, 128.0);
        assert_eq!(cfg.link_latency_us, 1.0);
        assert_eq!(cfg.interactive_pct, 30.0);
        assert_eq!(cfg.ttft_slo_ms, 40.0);
        assert!(!cfg.colocated());
        assert_eq!((cfg.serve.h_q, cfg.serve.h_k, cfg.serve.d_head), (64, 8, 128));
        assert_eq!(cfg.serve.sessions, 12);
        assert_eq!(cfg.serve.chunk_tokens, 1024, "worked example serves chunked");
        assert_eq!(cfg.serve.seed, 7);

        let documented = documented_keys(text);
        for key in &documented {
            assert!(
                *key == "topology"
                    || ATTENTION_KEYS.contains(key)
                    || SIM_KEYS.contains(key)
                    || SERVE_KEYS.contains(key)
                    || DISAGG_KEYS.contains(key),
                "examples/disagg.ini documents key '{key}' the parser does not read"
            );
        }
        for key in DISAGG_KEYS {
            assert!(
                documented.contains(&key),
                "examples/disagg.ini does not document the [disagg] key '{key}'"
            );
        }
    }

    #[test]
    fn tune_section_round_trips_and_validates() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // No [tune] section: no mode — the CLI applies its own default.
        let c = ExperimentConfig::parse(base).unwrap();
        assert!(c.tune.is_none());
        assert_eq!(c.tune_mode().unwrap(), None);

        // Explicit strategies land where docs/TUNING.md says.
        let ex = format!("{base}\n[tune]\nsearch = \"exhaustive\"\n");
        let mode = ExperimentConfig::parse(&ex).unwrap().tune_mode().unwrap();
        assert_eq!(mode, Some(crate::coordinator::SearchMode::Exhaustive));
        let beam = format!("{base}\n[tune]\nsearch = \"beam\"\nbeam_width = 3\n");
        let mode = ExperimentConfig::parse(&beam).unwrap().tune_mode().unwrap();
        assert_eq!(mode, Some(crate::coordinator::SearchMode::Beam { width: 3 }));

        // An empty section defaults to exhaustive; a bare beam search
        // gets the default width.
        let empty = format!("{base}\n[tune]\n");
        let mode = ExperimentConfig::parse(&empty).unwrap().tune_mode().unwrap();
        assert_eq!(mode, Some(crate::coordinator::SearchMode::Exhaustive));
        let bare = format!("{base}\n[tune]\nsearch = \"beam\"\n");
        let mode = ExperimentConfig::parse(&bare).unwrap().tune_mode().unwrap();
        assert_eq!(mode, Some(crate::coordinator::SearchMode::Beam { width: 2 }));

        // Degenerate sections are rejected with actionable messages.
        let bogus = format!("{base}\n[tune]\nsearch = \"random\"\n");
        let err = ExperimentConfig::parse(&bogus).unwrap().tune_mode().unwrap_err();
        assert!(err.contains("exhaustive or beam"), "{err}");
        let zero = format!("{base}\n[tune]\nsearch = \"beam\"\nbeam_width = 0\n");
        let err = ExperimentConfig::parse(&zero).unwrap().tune_mode().unwrap_err();
        assert!(err.contains("beam_width"), "{err}");
        let orphan = format!("{base}\n[tune]\nbeam_width = 2\n");
        let err = ExperimentConfig::parse(&orphan).unwrap().tune_mode().unwrap_err();
        assert!(err.contains("contradictory"), "{err}");
    }

    #[test]
    fn example_tune_file_stays_reconciled() {
        // Same contract as `example_cluster_file_stays_reconciled`, for
        // the worked autotuner workload (docs/TUNING.md): the file must
        // parse, request the decode pass and beam search it documents,
        // and every key its reference block documents must be one the
        // parser reads — with the full [tune] key set covered.
        let text = include_str!("../../../examples/tune.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let attn = c.attn().unwrap();
        assert_eq!((attn.h_q, attn.h_k, attn.n_ctx), (64, 8, 65536));
        assert_eq!(c.kernel().unwrap(), ExpKernel::Decode(8));
        assert_eq!(
            c.tune_mode().unwrap(),
            Some(crate::coordinator::SearchMode::Beam { width: 2 })
        );

        let documented = documented_keys(text);
        for key in &documented {
            assert!(
                *key == "topology"
                    || ATTENTION_KEYS.contains(key)
                    || SIM_KEYS.contains(key)
                    || TUNE_KEYS.contains(key),
                "examples/tune.ini documents key '{key}' the parser does not read"
            );
        }
        for key in TUNE_KEYS {
            assert!(
                documented.contains(&key),
                "examples/tune.ini does not document the [tune] key '{key}'"
            );
        }
    }

    #[test]
    fn trace_section_round_trips_and_validates() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // No [trace] section: no spec, no file, and the serving config
        // keeps the stationary generator (trace = None).
        let c = ExperimentConfig::parse(base).unwrap();
        assert!(c.trace.is_none());
        assert!(c.trace_spec().unwrap().is_none());
        assert_eq!(c.trace_file(), None);
        assert!(c.serve_config().unwrap().trace.is_none());

        // Every generator key lands where docs/SERVING.md §8 says, and
        // the serving config carries the generated schedule.
        let on = format!(
            "{base}\n[trace]\nshape = \"diurnal\"\nseed = 21\nsessions = 12\n\
             base_per_sec = 50\npeak_per_sec = 500\nperiod_sec = 0.5\nduty_pct = 20\n\
             prefill_lengths = \"1024,4096\"\ndecode_tokens = \"16,64\"\n\
             share_pct = 50\nshare_span = 512\ninteractive_pct = 25\n"
        );
        let c = ExperimentConfig::parse(&on).unwrap();
        let spec = c.trace_spec().unwrap().unwrap();
        assert_eq!(spec.shape, crate::workload::TraceShape::Diurnal);
        assert_eq!((spec.seed, spec.sessions), (21, 12));
        assert_eq!((spec.base_per_sec, spec.peak_per_sec), (50.0, 500.0));
        assert_eq!((spec.period_sec, spec.duty_pct), (0.5, 20.0));
        assert_eq!(spec.prefill_lengths, vec![1024, 4096]);
        assert_eq!(spec.decode_tokens, vec![16, 64]);
        assert_eq!((spec.share_pct, spec.share_span), (50.0, 512));
        assert_eq!(spec.interactive_pct, 25.0);
        let cfg = c.serve_config().unwrap();
        assert_eq!(cfg.trace.as_ref().map(|t| t.len()), Some(12));

        // A file-replay section defers loading to the CLI.
        let file = format!("{base}\n[trace]\nfile = \"examples/bursty.trace\"\n");
        let c = ExperimentConfig::parse(&file).unwrap();
        assert_eq!(c.trace_file(), Some("examples/bursty.trace"));
        assert!(c.trace_spec().unwrap().is_none());
        assert!(c.serve_config().unwrap().trace.is_none());

        // file + generator keys is contradictory.
        let both = format!("{base}\n[trace]\nfile = \"x.trace\"\nseed = 3\n");
        let err = ExperimentConfig::parse(&both).unwrap().trace_spec().unwrap_err();
        assert!(err.contains("contradictory"), "{err}");

        // Bad values error at parse time with [trace]-prefixed messages
        // instead of panicking inside the generator.
        for (frag, needle) in [
            ("shape = \"weekly\"", "unknown trace shape"),
            ("sessions = 0", "[trace] sessions"),
            ("base_per_sec = 0", "[trace] base_per_sec"),
            ("peak_per_sec = 1", "[trace] peak_per_sec"),
            ("period_sec = 0", "[trace] period_sec"),
            ("duty_pct = 200", "[trace] duty_pct"),
            ("prefill_lengths = \"0\"", "trace.prefill_lengths"),
            ("decode_tokens = \"4,zebra\"", "trace.decode_tokens"),
            ("share_pct = -1", "[trace] share_pct"),
            ("interactive_pct = 150", "[trace] interactive_pct"),
        ] {
            let bad = format!("{base}\n[trace]\n{frag}\n");
            let err = ExperimentConfig::parse(&bad).unwrap().trace_spec().unwrap_err();
            assert!(err.contains(needle), "{frag}: {err}");
            // The serving-config builder surfaces the same error.
            assert!(ExperimentConfig::parse(&bad).unwrap().serve_config().is_err(), "{frag}");
        }
    }

    #[test]
    fn faults_section_builds_the_spec_and_rejects_garbage() {
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        // No [faults] section: the inject-nothing default.
        let c = ExperimentConfig::parse(base).unwrap();
        assert!(c.faults.is_none());
        assert!(c.fault_spec().unwrap().is_none());

        // An explicit schedule lands verbatim.
        let events = format!("{base}\n[faults]\nevents = \"1:0.2:0.4,0:0.5:0.6\"\n");
        let spec = ExperimentConfig::parse(&events).unwrap().fault_spec().unwrap();
        assert_eq!(spec.events, "1:0.2:0.4,0:0.5:0.6");
        assert!(!spec.is_none());

        // Seeded-plan keys land with defaults for the rest.
        let seeded = format!("{base}\n[faults]\ncount = 2\nseed = 99\nhorizon_sec = 0.25\n");
        let spec = ExperimentConfig::parse(&seeded).unwrap().fault_spec().unwrap();
        assert_eq!((spec.count, spec.seed), (2, 99));
        assert_eq!(spec.horizon_sec, 0.25);
        assert!(!spec.is_none());

        // Degenerate sections are rejected at parse time with
        // [faults]-prefixed messages.
        let both = format!("{base}\n[faults]\nevents = \"0:0.1:0.2\"\ncount = 2\n");
        let err = ExperimentConfig::parse(&both).unwrap().fault_spec().unwrap_err();
        assert!(err.contains("contradictory"), "{err}");
        let garbled = format!("{base}\n[faults]\nevents = \"0:0.1\"\n");
        let err = ExperimentConfig::parse(&garbled).unwrap().fault_spec().unwrap_err();
        assert!(err.contains("[faults]"), "{err}");
        let horizon = format!("{base}\n[faults]\ncount = 2\nhorizon_sec = 0\n");
        let err = ExperimentConfig::parse(&horizon).unwrap().fault_spec().unwrap_err();
        assert!(err.contains("horizon_sec"), "{err}");
    }

    #[test]
    fn serve_section_rejects_generator_poisons_at_parse_time() {
        // The values that used to reach SessionGenerator::new's asserts
        // (and panic) from an experiment file must instead surface as
        // config errors naming the offending key.
        let base = r#"
[attention]
batch = 1
h_q = 16
h_k = 8
n_ctx = 8192
d_head = 64
"#;
        for (frag, needle) in [
            ("arrival_per_sec = 0", "arrival_per_sec"),
            ("arrival_per_sec = -80", "arrival_per_sec"),
            ("sessions = 0", "sessions"),
            ("max_active = 0", "max_active"),
            ("steps = 0", "max_steps"),
            ("kv_bucket = 0", "kv_bucket"),
            ("prefill_lengths = \"999999\"", "KV capacity"),
        ] {
            let bad = format!("{base}\n[serve]\n{frag}\n");
            let err = ExperimentConfig::parse(&bad).unwrap().serve_config().unwrap_err();
            assert!(err.contains(needle), "{frag}: {err}");
        }
    }

    #[test]
    fn example_serve_burst_file_stays_reconciled() {
        // Same contract as `example_serve_file_builds_the_serving_config`,
        // for the worked bursty-trace scenario (docs/SERVING.md §8): the
        // file must parse, generate the trace it documents, and every
        // key its reference block documents must be one the parser reads
        // — with the full [trace] key set covered.
        let text = include_str!("../../../examples/serve_burst.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let spec = c.trace_spec().unwrap().expect("worked example generates its trace");
        assert_eq!(spec.shape, crate::workload::TraceShape::Bursty);
        let cfg = c.serve_config().unwrap();
        let trace = cfg.trace.as_ref().expect("serving config carries the trace");
        assert_eq!(trace.len(), spec.sessions);
        assert!(trace.sessions().iter().all(|s| s.prefill <= cfg.kv_cap));

        let documented = documented_keys(text);
        for key in &documented {
            assert!(
                *key == "topology"
                    || ATTENTION_KEYS.contains(key)
                    || SIM_KEYS.contains(key)
                    || SERVE_KEYS.contains(key)
                    || TRACE_KEYS.contains(key),
                "examples/serve_burst.ini documents key '{key}' the parser does not read"
            );
        }
        for key in TRACE_KEYS {
            assert!(
                documented.contains(&key),
                "examples/serve_burst.ini does not document the [trace] key '{key}'"
            );
        }
    }

    #[test]
    fn example_faults_file_stays_reconciled() {
        // Same contract as `example_cluster_file_stays_reconciled`, for
        // the worked fault-injection scenario (docs/SERVING.md §9): the
        // file must parse, build the cluster it documents, resolve its
        // fault plan against that cluster, and every key its reference
        // block documents must be one the parser reads — with the full
        // [faults] key set covered.
        let text = include_str!("../../../examples/faults.ini");
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.topology, "mi300x");
        let cluster = c.cluster_topology().unwrap();
        let spec = c.fault_spec().unwrap();
        assert!(!spec.is_none(), "worked example injects faults");
        let plan = spec.resolve(cluster.num_devices()).unwrap();
        assert!(!plan.is_empty());
        c.serve_config().unwrap();

        let documented = documented_keys(text);
        for key in &documented {
            assert!(
                *key == "topology"
                    || ATTENTION_KEYS.contains(key)
                    || SIM_KEYS.contains(key)
                    || SERVE_KEYS.contains(key)
                    || CLUSTER_KEYS.contains(key)
                    || FAULTS_KEYS.contains(key),
                "examples/faults.ini documents key '{key}' the parser does not read"
            );
        }
        for key in FAULTS_KEYS {
            assert!(
                documented.contains(&key),
                "examples/faults.ini does not document the [faults] key '{key}'"
            );
        }
    }

    #[test]
    fn rejects_invalid_attention() {
        let toml = r#"
[attention]
batch = 1
h_q = 6
h_k = 4
n_ctx = 2048
d_head = 64
"#;
        let c = ExperimentConfig::parse(toml).unwrap();
        assert!(c.attn().is_err());
    }
}
