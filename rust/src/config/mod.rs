//! Experiment configuration files: INI-style `[section] key = value`
//! (see `rust/src/util/ini.rs` — the toml crate is unavailable offline,
//! and the subset used here parses identically). Example:
//!
//! ```ini
//! topology = "mi300x"
//!
//! [attention]
//! batch = 2
//! h_q = 64
//! h_k = 8
//! n_ctx = 8192
//! d_head = 128
//!
//! [sim]
//! policy = "shf"
//! generations = 2
//! ```

use crate::attn::{AttnConfig, KernelKind};
use crate::mapping::Policy;
use crate::sim::SimConfig;
use crate::topology::{presets, Topology};
use crate::util::ini::Ini;

/// Top-level experiment file.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Topology preset name.
    pub topology: String,
    pub attention: AttentionSection,
    pub sim: SimSection,
}

#[derive(Debug, Clone)]
pub struct AttentionSection {
    pub batch: usize,
    pub h_q: usize,
    pub h_k: Option<usize>,
    pub n_ctx: usize,
    pub d_head: usize,
    pub block_m: usize,
    pub block_n: usize,
    pub causal: bool,
    pub dtype_bytes: usize,
}

#[derive(Debug, Clone, Default)]
pub struct SimSection {
    pub policy: Option<String>,
    pub backward: bool,
    pub generations: Option<usize>,
    pub jitter_denom: Option<u64>,
    pub launch_stagger: Option<u64>,
    pub prefetch_depth: Option<u32>,
    pub compute_efficiency: Option<f64>,
    pub seed: Option<u64>,
}

impl ExperimentConfig {
    pub fn parse(text: &str) -> Result<Self, String> {
        let ini = Ini::parse(text)?;
        if !ini.has_section("attention") {
            return Err("missing [attention] section".into());
        }
        let attention = AttentionSection {
            batch: ini
                .get_parsed("attention", "batch")?
                .ok_or("attention.batch required")?,
            h_q: ini
                .get_parsed("attention", "h_q")?
                .ok_or("attention.h_q required")?,
            h_k: ini.get_parsed("attention", "h_k")?,
            n_ctx: ini
                .get_parsed("attention", "n_ctx")?
                .ok_or("attention.n_ctx required")?,
            d_head: ini
                .get_parsed("attention", "d_head")?
                .ok_or("attention.d_head required")?,
            block_m: ini.get_parsed("attention", "block_m")?.unwrap_or(128),
            block_n: ini.get_parsed("attention", "block_n")?.unwrap_or(64),
            causal: ini.get_parsed("attention", "causal")?.unwrap_or(false),
            dtype_bytes: ini.get_parsed("attention", "dtype_bytes")?.unwrap_or(2),
        };
        let sim = SimSection {
            policy: ini.get("sim", "policy").map(|s| s.to_string()),
            backward: ini.get_parsed("sim", "backward")?.unwrap_or(false),
            generations: ini.get_parsed("sim", "generations")?,
            jitter_denom: ini.get_parsed("sim", "jitter_denom")?,
            launch_stagger: ini.get_parsed("sim", "launch_stagger")?,
            prefetch_depth: ini.get_parsed("sim", "prefetch_depth")?,
            compute_efficiency: ini.get_parsed("sim", "compute_efficiency")?,
            seed: ini.get_parsed("sim", "seed")?,
        };
        Ok(ExperimentConfig {
            topology: ini.get("", "topology").unwrap_or("mi300x").to_string(),
            attention,
            sim,
        })
    }

    pub fn topology(&self) -> Result<Topology, String> {
        presets::by_name(&self.topology)
            .ok_or_else(|| format!("unknown topology preset '{}'", self.topology))
    }

    pub fn attn(&self) -> Result<AttnConfig, String> {
        let a = &self.attention;
        let cfg = AttnConfig {
            batch: a.batch,
            h_q: a.h_q,
            h_k: a.h_k.unwrap_or(a.h_q),
            n_ctx: a.n_ctx,
            d_head: a.d_head,
            block_m: a.block_m,
            block_n: a.block_n,
            causal: a.causal,
            dtype_bytes: a.dtype_bytes,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn sim(&self, policy: Policy) -> Result<SimConfig, String> {
        let topo = self.topology()?;
        let s = &self.sim;
        let mut cfg = match s.generations {
            Some(g) => SimConfig::sampled(policy, &topo, g),
            None => SimConfig::forward(policy),
        };
        if s.backward {
            cfg.kernel = KernelKind::BwdDkDv;
            cfg.compute_overhead = SimConfig::backward(policy).compute_overhead;
        }
        if let Some(j) = s.jitter_denom {
            cfg.jitter_denom = j;
        }
        if let Some(ls) = s.launch_stagger {
            cfg.launch_stagger = ls;
        }
        if let Some(p) = s.prefetch_depth {
            cfg.prefetch_depth = p;
        }
        if let Some(e) = s.compute_efficiency {
            cfg.compute_efficiency = e;
        }
        if let Some(seed) = s.seed {
            cfg.seed = seed;
        }
        Ok(cfg)
    }

    /// Policy list: explicit one, or all four.
    pub fn policies(&self) -> Result<Vec<Policy>, String> {
        match &self.sim.policy {
            Some(p) => Ok(vec![p.parse()?]),
            None => Ok(crate::mapping::ALL_POLICIES.to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
topology = "mi300x"

[attention]
batch = 2
h_q = 64
h_k = 8
n_ctx = 8192
d_head = 128

[sim]
policy = "shf"
generations = 2
seed = 42
"#;

    #[test]
    fn parse_sample() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let topo = c.topology().unwrap();
        assert_eq!(topo.num_xcds, 8);
        let attn = c.attn().unwrap();
        assert_eq!(attn.h_k, 8);
        assert_eq!(attn.block_m, 128); // default
        let pols = c.policies().unwrap();
        assert_eq!(pols, vec![Policy::SwizzledHeadFirst]);
        let sim = c.sim(pols[0]).unwrap();
        assert_eq!(sim.seed, 42);
        assert!(sim.max_wg_completions > 0);
    }

    #[test]
    fn defaults_h_k_to_h_q() {
        let toml = r#"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64
"#;
        let c = ExperimentConfig::parse(toml).unwrap();
        assert_eq!(c.attn().unwrap().h_k, 8);
        assert_eq!(c.policies().unwrap().len(), 4);
    }

    #[test]
    fn rejects_bad_topo() {
        let toml = r#"
topology = "h100"
[attention]
batch = 1
h_q = 8
n_ctx = 2048
d_head = 64
"#;
        let c = ExperimentConfig::parse(toml).unwrap();
        assert!(c.topology().is_err());
    }

    #[test]
    fn rejects_invalid_attention() {
        let toml = r#"
[attention]
batch = 1
h_q = 6
h_k = 4
n_ctx = 2048
d_head = 64
"#;
        let c = ExperimentConfig::parse(toml).unwrap();
        assert!(c.attn().is_err());
    }
}
