//! Paged KV block pool with cross-session prefix sharing
//! (docs/KVCACHE.md): fixed-size KV blocks keyed by canonical prefix
//! hash, refcounted copy-on-write prefix trie, and LRU eviction of
//! refcount-0 childless nodes under a byte budget.
//!
//! The serving loop ([`crate::coordinator::serve_decode`]) consults the
//! pool at admission: the leading run of a prompt's blocks that is
//! already resident is *credited* (those prefill tokens are charged
//! zero — another session already prefilled them), and only the
//! non-shared suffix is priced. Sessions hold a refcount *lease* on
//! every block they hit or insert until they retire, which is what
//! makes eviction safe: a live (refcount > 0) block is never evicted,
//! and the copy-on-write rule is structural — a session forking off a
//! shared prefix inserts only its diverging suffix blocks (keyed by its
//! own session id), while the shared ancestors' refcounts climb.
//!
//! The pool is deliberately a pure data structure (no clocks, no
//! driver handle): determinism is what lets `tests/properties.rs`
//! check it differentially against a naive full-prefix map and lets
//! the serving goldens stay byte-for-byte reproducible.

use std::collections::HashMap;

use crate::util::rng::mix;

/// Salt distinguishing the canonical shared-prefix key stream from
/// per-session private keys (which are salted by `session_id + 1`).
const SHARED_SALT: u64 = 0;

/// Bytes one KV block occupies in HBM: `block_tokens` K and V vectors
/// across every KV head at the deployment's precision. With the worked
/// llama3-70b geometry (8 KV heads x 128 dims x 2 bytes) a 256-token
/// block is exactly 1 MiB.
pub fn block_bytes(block_tokens: usize, h_k: usize, d_head: usize, dtype_bytes: usize) -> u64 {
    2 * (block_tokens as u64) * (h_k as u64) * (d_head as u64) * (dtype_bytes as u64)
}

/// Canonical block-key sequence for a prompt: block `j` covers prompt
/// tokens `[j*bt, min((j+1)*bt, prefill))`. Blocks that lie entirely
/// inside the session's shared prefix hash from the canonical shared
/// stream (identical across sessions — the cross-session hit path);
/// every later block hashes from the session's own id, so private
/// suffixes can never collide into another session's cache line — the
/// copy-on-write fork point falls out of the keying.
pub fn prompt_keys(
    session_id: u64,
    prefill: usize,
    shared_prefix: usize,
    block_tokens: usize,
) -> Vec<u64> {
    if block_tokens == 0 || prefill == 0 {
        return Vec::new();
    }
    let blocks = prefill.div_ceil(block_tokens);
    let shared = shared_prefix.min(prefill);
    (0..blocks)
        .map(|j| {
            let salt = if (j + 1) * block_tokens <= shared { SHARED_SALT } else { session_id + 1 };
            mix(salt.rotate_left(17) ^ mix(j as u64 ^ 0x9E3779B97F4A7C15))
        })
        .collect()
}

/// One trie node: a resident KV block at a specific position of a
/// specific prefix chain.
#[derive(Debug)]
struct Node {
    key: u64,
    parent: Option<usize>,
    children: HashMap<u64, usize>,
    refs: usize,
    /// Monotonic op clock of the last acquire that touched this node
    /// (hit or insert) — the LRU eviction order.
    last_use: u64,
    /// Monotonic insertion id, the deterministic LRU tie-break.
    insert_id: u64,
}

/// What [`KvPool::acquire`] did for one prompt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Acquire {
    /// Leading blocks already resident (cross-session hits): these
    /// prompt tokens are charged zero.
    pub credited_blocks: usize,
    /// Block indices (positions in the key sequence) newly inserted by
    /// this acquire — the blocks whose placement the serving loop
    /// scores for XCD affinity.
    pub inserted: Vec<usize>,
}

/// Refcounted copy-on-write prefix trie over fixed-size KV blocks with
/// a byte budget and LRU eviction of refcount-0 childless nodes. See
/// the module docs for the serving-loop contract.
#[derive(Debug)]
pub struct KvPool {
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    roots: HashMap<u64, usize>,
    /// Per-session lease: the node path acquired at admission, released
    /// when the session retires.
    leases: HashMap<u64, Vec<usize>>,
    block_bytes: u64,
    capacity_bytes: u64,
    used_bytes: u64,
    peak_used_bytes: u64,
    clock: u64,
    next_insert_id: u64,
    evictions: u64,
    hit_blocks: u64,
    miss_blocks: u64,
}

impl KvPool {
    /// A pool of `block_bytes`-sized blocks under `capacity_bytes`
    /// (0 = unlimited).
    pub fn new(block_bytes: u64, capacity_bytes: u64) -> Self {
        assert!(block_bytes > 0, "block_bytes must be > 0");
        KvPool {
            nodes: Vec::new(),
            free: Vec::new(),
            roots: HashMap::new(),
            leases: HashMap::new(),
            block_bytes,
            capacity_bytes: if capacity_bytes == 0 { u64::MAX } else { capacity_bytes },
            used_bytes: 0,
            peak_used_bytes: 0,
            clock: 0,
            next_insert_id: 0,
            evictions: 0,
            hit_blocks: 0,
            miss_blocks: 0,
        }
    }

    fn node(&self, idx: usize) -> &Node {
        self.nodes[idx].as_ref().expect("live node")
    }

    fn node_mut(&mut self, idx: usize) -> &mut Node {
        self.nodes[idx].as_mut().expect("live node")
    }

    /// Acquire a lease on a prompt's block chain. Walks the trie along
    /// `keys`: the leading resident run is credited (and its refcounts
    /// climb — the copy-on-write sharing), then the remaining blocks
    /// are inserted while the budget allows, evicting refcount-0
    /// childless nodes in LRU order to make room. Blocks that do not
    /// fit are simply not pooled (the serving loop prefills them
    /// normally, uncached). A session may hold at most one lease;
    /// re-acquiring without [`Self::release`] is a caller bug.
    pub fn acquire(&mut self, session: u64, keys: &[u64]) -> Acquire {
        assert!(
            !self.leases.contains_key(&session),
            "session {session} already holds a KV lease"
        );
        self.clock += 1;
        let clock = self.clock;
        let mut path: Vec<usize> = Vec::with_capacity(keys.len());
        let mut credited = 0usize;
        let mut inserted = Vec::new();
        let mut cursor: Option<usize> = None;
        let mut walking = true;
        for (j, &key) in keys.iter().enumerate() {
            if walking {
                let child = match cursor {
                    None => self.roots.get(&key).copied(),
                    Some(c) => self.node(c).children.get(&key).copied(),
                };
                if let Some(idx) = child {
                    let n = self.node_mut(idx);
                    n.refs += 1;
                    n.last_use = clock;
                    path.push(idx);
                    cursor = Some(idx);
                    credited += 1;
                    self.hit_blocks += 1;
                    continue;
                }
                walking = false;
            }
            self.miss_blocks += 1;
            if !self.make_room() {
                break; // budget exhausted by live blocks: rest runs unpooled
            }
            let idx = self.alloc_node(Node {
                key,
                parent: cursor,
                children: HashMap::new(),
                refs: 1,
                last_use: clock,
                insert_id: 0, // set in alloc_node
            });
            match cursor {
                None => {
                    self.roots.insert(key, idx);
                }
                Some(c) => {
                    self.node_mut(c).children.insert(key, idx);
                }
            }
            self.used_bytes += self.block_bytes;
            self.peak_used_bytes = self.peak_used_bytes.max(self.used_bytes);
            path.push(idx);
            cursor = Some(idx);
            inserted.push(j);
        }
        self.leases.insert(session, path);
        Acquire { credited_blocks: credited, inserted }
    }

    /// Release a session's lease: every block on its path drops one
    /// refcount. Refcount-0 blocks stay resident (they are the shared
    /// cache) until capacity pressure evicts them. Unknown sessions are
    /// a no-op, so the serving loop may release unconditionally at
    /// retirement even for sessions admitted before sharing engaged.
    pub fn release(&mut self, session: u64) {
        let Some(path) = self.leases.remove(&session) else { return };
        for idx in path {
            let n = self.node_mut(idx);
            debug_assert!(n.refs > 0, "release underflow");
            n.refs -= 1;
        }
    }

    /// Length of the leading resident run for a key chain, without
    /// touching refcounts or LRU state (differential-test probe).
    pub fn probe(&self, keys: &[u64]) -> usize {
        let mut cursor: Option<usize> = None;
        let mut run = 0;
        for &key in keys {
            let child = match cursor {
                None => self.roots.get(&key).copied(),
                Some(c) => self.node(c).children.get(&key).copied(),
            };
            match child {
                Some(idx) => {
                    run += 1;
                    cursor = Some(idx);
                }
                None => break,
            }
        }
        run
    }

    /// Free one block's worth of budget, evicting refcount-0 childless
    /// nodes in LRU order (`(last_use, insert_id)` ascending) until a
    /// block fits. Returns false when every resident block is live —
    /// nothing may be evicted, the caller's block stays unpooled.
    fn make_room(&mut self) -> bool {
        if self.block_bytes > self.capacity_bytes {
            return false;
        }
        while self.used_bytes + self.block_bytes > self.capacity_bytes {
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
                .min_by_key(|(_, n)| (n.last_use, n.insert_id))
                .map(|(i, _)| i);
            let Some(idx) = victim else { return false };
            self.evict(idx);
        }
        true
    }

    fn evict(&mut self, idx: usize) {
        let n = self.nodes[idx].take().expect("evict live node");
        debug_assert!(n.refs == 0 && n.children.is_empty());
        match n.parent {
            None => {
                self.roots.remove(&n.key);
            }
            Some(p) => {
                self.node_mut(p).children.remove(&n.key);
            }
        }
        self.free.push(idx);
        self.used_bytes -= self.block_bytes;
        self.evictions += 1;
    }

    fn alloc_node(&mut self, mut n: Node) -> usize {
        n.insert_id = self.next_insert_id;
        self.next_insert_id += 1;
        match self.free.pop() {
            Some(idx) => {
                self.nodes[idx] = Some(n);
                idx
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    /// Bytes currently resident.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// High-water mark of [`Self::used_bytes`] (the capacity invariant
    /// `tests/serving_invariants.rs` checks).
    pub fn peak_used_bytes(&self) -> u64 {
        self.peak_used_bytes
    }

    /// The configured budget (`u64::MAX` when unlimited).
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Blocks resident right now.
    pub fn resident_blocks(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    /// Blocks evicted so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// (cross-session hit blocks, inserted-or-unpooled miss blocks)
    /// across every acquire.
    pub fn hit_miss_blocks(&self) -> (u64, u64) {
        (self.hit_blocks, self.miss_blocks)
    }

    /// Sum of refcounts across resident nodes — conservation says this
    /// equals the summed lease lengths ([`Self::leased_blocks`]).
    pub fn total_refs(&self) -> usize {
        self.nodes.iter().flatten().map(|n| n.refs).sum()
    }

    /// Sum of lease path lengths across live sessions.
    pub fn leased_blocks(&self) -> usize {
        self.leases.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn block_bytes_matches_worked_geometry() {
        // llama3-70b serving geometry: 256-token block = exactly 1 MiB.
        assert_eq!(block_bytes(256, 8, 128, 2), MB);
    }

    #[test]
    fn prompt_keys_share_prefix_and_fork_suffix() {
        // Two sessions sharing a 512-token prefix over 256-token blocks
        // agree on the first two keys and diverge after — the
        // copy-on-write fork is purely in the keying.
        let a = prompt_keys(1, 1024, 512, 256);
        let b = prompt_keys(2, 1024, 512, 256);
        assert_eq!(a.len(), 4);
        assert_eq!(a[..2], b[..2], "shared span keys are canonical");
        assert_ne!(a[2], b[2], "private suffixes never collide");
        // A partial tail block never counts as shared.
        let c = prompt_keys(3, 600, 600, 256);
        let d = prompt_keys(4, 600, 600, 256);
        assert_eq!(c[..2], d[..2]);
        assert_ne!(c[2], d[2], "partial tail block stays private");
        assert!(prompt_keys(1, 0, 0, 256).is_empty());
        assert!(prompt_keys(1, 1024, 0, 0).is_empty());
    }

    #[test]
    fn second_session_hits_shared_prefix_and_forks() {
        let mut pool = KvPool::new(MB, 0);
        let a = prompt_keys(1, 1024, 512, 256);
        let b = prompt_keys(2, 1024, 512, 256);
        let first = pool.acquire(1, &a);
        assert_eq!(first.credited_blocks, 0);
        assert_eq!(first.inserted, vec![0, 1, 2, 3]);
        let second = pool.acquire(2, &b);
        assert_eq!(second.credited_blocks, 2, "shared span is credited");
        assert_eq!(second.inserted, vec![2, 3], "only the fork is copied");
        assert_eq!(pool.resident_blocks(), 6);
        assert_eq!(pool.used_bytes(), 6 * MB);
        assert_eq!(pool.total_refs(), pool.leased_blocks());
        // The shared ancestors carry both sessions' refs.
        pool.release(1);
        pool.release(2);
        assert_eq!(pool.total_refs(), 0);
        assert_eq!(pool.resident_blocks(), 6, "refcount-0 blocks stay cached");
    }

    #[test]
    fn live_blocks_are_never_evicted() {
        // Capacity of 2 blocks, session 1 holds both live.
        let mut pool = KvPool::new(MB, 2 * MB);
        let a = pool.acquire(1, &prompt_keys(1, 512, 0, 256));
        assert_eq!(a.inserted.len(), 2);
        // Session 2 wants 2 more: nothing evictable, rest runs unpooled.
        let b = pool.acquire(2, &prompt_keys(2, 512, 0, 256));
        assert_eq!(b.credited_blocks, 0);
        assert!(b.inserted.is_empty(), "live blocks must not be evicted");
        assert_eq!(pool.evictions(), 0);
        assert_eq!(pool.used_bytes(), 2 * MB);
        pool.release(2);
        pool.release(1);
        // Now refcount-0: session 3 evicts LRU and fits.
        let c = pool.acquire(3, &prompt_keys(3, 512, 0, 256));
        assert_eq!(c.inserted.len(), 2);
        assert_eq!(pool.evictions(), 2);
        assert!(pool.used_bytes() <= pool.capacity_bytes());
    }

    #[test]
    fn evicted_prefix_readmits_as_misses_exactly_once() {
        // The re-prefill-exactly-once story: a shared prefix that was
        // evicted must miss on readmission (it will be re-prefilled),
        // and from then on hit again.
        let shared = prompt_keys(0, 512, 512, 256); // note: 512/256 = 2 full blocks
        let mut pool = KvPool::new(MB, 2 * MB);
        pool.acquire(1, &shared[..2]);
        pool.release(1);
        // Force eviction with an unrelated 2-block working set.
        pool.acquire(2, &prompt_keys(9, 512, 0, 256));
        assert_eq!(pool.evictions(), 2, "idle shared prefix evicted");
        pool.release(2);
        let re = pool.acquire(3, &shared[..2]);
        assert_eq!(re.credited_blocks, 0, "evicted prefix re-prefills");
        assert_eq!(re.inserted.len(), 2);
        pool.release(3);
        let again = pool.acquire(4, &shared[..2]);
        assert_eq!(again.credited_blocks, 2, "resident again after one re-prefill");
    }

    #[test]
    fn eviction_is_lru_over_refcount_zero_leaves() {
        let mut pool = KvPool::new(MB, 3 * MB);
        pool.acquire(1, &prompt_keys(1, 256, 0, 256)); // block A, clock 1
        pool.acquire(2, &prompt_keys(2, 256, 0, 256)); // block B, clock 2
        pool.release(1);
        pool.release(2);
        // Touch A: it becomes most-recent.
        let touched = pool.acquire(3, &prompt_keys(1, 256, 0, 256));
        assert_eq!(touched.credited_blocks, 1);
        pool.release(3);
        // Two new blocks: B (LRU) goes first, then A.
        pool.acquire(4, &prompt_keys(4, 512, 0, 256));
        assert_eq!(pool.evictions(), 1);
        assert_eq!(pool.probe(&prompt_keys(1, 256, 0, 256)), 1, "recently-touched A survives");
        assert_eq!(pool.probe(&prompt_keys(2, 256, 0, 256)), 0, "LRU B evicted");
    }

    #[test]
    fn zero_capacity_means_unlimited_and_tiny_budget_pools_nothing() {
        let mut pool = KvPool::new(MB, 0);
        assert_eq!(pool.capacity_bytes(), u64::MAX);
        let a = pool.acquire(1, &prompt_keys(1, 64 * 256, 0, 256));
        assert_eq!(a.inserted.len(), 64);

        // Budget smaller than one block: nothing is ever pooled.
        let mut tiny = KvPool::new(MB, MB / 2);
        let b = tiny.acquire(1, &prompt_keys(1, 512, 0, 256));
        assert!(b.inserted.is_empty());
        assert_eq!(tiny.used_bytes(), 0);
    }

    #[test]
    #[should_panic(expected = "already holds")]
    fn double_acquire_is_a_caller_bug() {
        let mut pool = KvPool::new(MB, 0);
        pool.acquire(1, &prompt_keys(1, 256, 0, 256));
        pool.acquire(1, &prompt_keys(1, 256, 0, 256));
    }
}
