//! Bandwidth-budgeted HBM queue with MSHR merging.

use std::collections::VecDeque;

use crate::util::fxhash::FastMap;

/// Opaque handle identifying an outstanding request.
pub type RequestId = u64;

#[derive(Debug, Clone)]
struct Request {
    id: RequestId,
    /// Slot that first issued the fetch (hit/miss attribution; read
    /// back via the MSHR file, kept here for debug dumps).
    #[allow(dead_code)]
    origin: u32,
    /// XCD whose L2 will be filled.
    xcd: u32,
    /// Tile key being fetched.
    key: u64,
    /// Bytes remaining to transfer.
    remaining: u64,
    /// Total bytes of the tile (for the completion record).
    bytes: u32,
    /// Tick at which fixed latency has elapsed and transfer may begin.
    ready_at: u64,
}

/// A finished fill, to be inserted into `xcd`'s L2 and used to wake the
/// workgroups waiting on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// The request's id.
    pub id: RequestId,
    /// XCD whose L2 requested the fill.
    pub xcd: u32,
    /// Tile key being filled.
    pub key: u64,
    /// Fill size in bytes.
    pub bytes: u32,
}

/// Aggregate traffic statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct HbmStats {
    /// Total bytes transferred from HBM.
    pub bytes_read: u64,
    /// Demand requests issued (post-MSHR-merge).
    pub requests: u64,
    /// Requests absorbed by an in-flight MSHR (same XCD + tile).
    pub mshr_merges: u64,
    /// Ticks during which the queue was non-empty (utilization proxy).
    pub busy_ticks: u64,
    /// Sum of queue depth sampled each busy tick (avg depth = /busy_ticks).
    pub queue_depth_sum: u64,
    /// Write traffic (outputs), accounted against bandwidth.
    pub bytes_written: u64,
}

impl HbmStats {
    /// Mean queue depth over the run (contention indicator).
    pub fn avg_queue_depth(&self) -> f64 {
        if self.busy_ticks == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.busy_ticks as f64
        }
    }
}

/// The HBM model. Drive it with `request` / `write` and call `step` once
/// per simulator tick; completions wake waiting workgroups.
#[derive(Debug)]
pub struct HbmModel {
    /// Bytes the memory system can deliver per tick (device aggregate).
    bytes_per_tick: u64,
    /// Fixed access latency in ticks before a request starts transferring.
    latency_ticks: u64,
    queue: VecDeque<Request>,
    /// (xcd, key) -> (RequestId, origin slot) of the in-flight fetch
    /// (the MSHR file).
    inflight: FastMap<(u32, u64), (RequestId, u32)>,
    next_id: RequestId,
    /// Pending write bytes (drained at the same budget, lower priority).
    write_backlog: u64,
    stats: HbmStats,
}

impl HbmModel {
    /// An idle HBM model with the given bandwidth and base latency.
    pub fn new(bytes_per_tick: u64, latency_ticks: u64) -> Self {
        assert!(bytes_per_tick > 0);
        HbmModel {
            bytes_per_tick,
            latency_ticks,
            queue: VecDeque::new(),
            inflight: FastMap::default(),
            next_id: 0,
            write_backlog: 0,
            stats: HbmStats::default(),
        }
    }

    /// Accumulated traffic statistics.
    pub fn stats(&self) -> &HbmStats {
        &self.stats
    }

    /// The modeled bandwidth budget per tick.
    pub fn bytes_per_tick(&self) -> u64 {
        self.bytes_per_tick
    }

    /// Outstanding demand requests (post-merge).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Estimated ticks to drain the current backlog — the queue-delay
    /// feedback the simulator uses for stall accounting.
    pub fn backlog_ticks(&self) -> u64 {
        let bytes: u64 =
            self.queue.iter().map(|r| r.remaining).sum::<u64>() + self.write_backlog;
        bytes.div_ceil(self.bytes_per_tick)
    }

    /// Is a fetch of (xcd, key) already outstanding?
    pub fn is_inflight(&self, xcd: u32, key: u64) -> bool {
        self.inflight.contains_key(&(xcd, key))
    }

    /// Slot that first issued the outstanding fetch of (xcd, key), if any.
    /// A demand that merges into ANOTHER slot's fetch is true inter-WG
    /// sharing (counted as an L2 hit by the engine); merging into one's
    /// own still-pending prefetch is a miss the prefetch failed to hide.
    pub fn inflight_origin(&self, xcd: u32, key: u64) -> Option<u32> {
        self.inflight.get(&(xcd, key)).map(|&(_, origin)| origin)
    }

    /// Issue a demand read of `key` (`bytes` wide) on behalf of `xcd`.
    /// Returns the request id; if an identical (xcd, key) fetch is already
    /// in flight the ids are equal (MSHR merge) and no new traffic is
    /// generated.
    pub fn request(&mut self, now: u64, xcd: u32, key: u64, bytes: u32, origin: u32) -> RequestId {
        if let Some(&(id, _)) = self.inflight.get(&(xcd, key)) {
            self.stats.mshr_merges += 1;
            return id;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            origin,
            xcd,
            key,
            remaining: bytes as u64,
            bytes,
            ready_at: now + self.latency_ticks,
        });
        self.inflight.insert((xcd, key), (id, origin));
        self.stats.requests += 1;
        self.stats.bytes_read += bytes as u64;
        id
    }

    /// Account output (store) traffic. Writes contend for the same budget
    /// but never stall a workgroup directly (write-back, fire and forget).
    pub fn write(&mut self, bytes: u64) {
        self.write_backlog += bytes;
        self.stats.bytes_written += bytes;
    }

    /// Advance one tick: spend the bandwidth budget on queued reads
    /// (FIFO), then leftover budget on the write backlog. Returns the
    /// fills completed this tick.
    pub fn step(&mut self, now: u64) -> Vec<Completion> {
        let mut completions = Vec::new();
        if self.queue.is_empty() && self.write_backlog == 0 {
            return completions;
        }
        self.stats.busy_ticks += 1;
        self.stats.queue_depth_sum += self.queue.len() as u64;

        let mut budget = self.bytes_per_tick;
        while budget > 0 {
            let Some(front) = self.queue.front_mut() else { break };
            if front.ready_at > now {
                // Head-of-line latency not yet elapsed; model simple
                // in-order service (no bypass) for determinism.
                break;
            }
            let take = front.remaining.min(budget);
            front.remaining -= take;
            budget -= take;
            if front.remaining == 0 {
                let r = self.queue.pop_front().unwrap();
                self.inflight.remove(&(r.xcd, r.key));
                completions.push(Completion {
                    id: r.id,
                    xcd: r.xcd,
                    key: r.key,
                    bytes: r.bytes,
                });
            }
        }
        // Drain writes with leftover budget.
        let wtake = self.write_backlog.min(budget);
        self.write_backlog -= wtake;
        completions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_request_completes_after_latency_and_transfer() {
        let mut hbm = HbmModel::new(100, 2);
        hbm.request(0, 0, 42, 250, 0);
        assert!(hbm.step(0).is_empty()); // latency
        assert!(hbm.step(1).is_empty()); // latency
        assert!(hbm.step(2).is_empty()); // 100/250
        assert!(hbm.step(3).is_empty()); // 200/250
        let done = hbm.step(4); // 250/250
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].key, 42);
        assert_eq!(done[0].bytes, 250);
    }

    #[test]
    fn mshr_merges_same_xcd_same_key() {
        let mut hbm = HbmModel::new(100, 0);
        let a = hbm.request(0, 3, 7, 100, 0);
        let b = hbm.request(0, 3, 7, 100, 0);
        assert_eq!(a, b);
        assert_eq!(hbm.stats().requests, 1);
        assert_eq!(hbm.stats().mshr_merges, 1);
        assert_eq!(hbm.stats().bytes_read, 100);
    }

    #[test]
    fn no_merge_across_xcds_models_replication_traffic() {
        // The Naive Head-first pathology: 8 XCDs all fetch the same tile.
        let mut hbm = HbmModel::new(1000, 0);
        for xcd in 0..8 {
            hbm.request(0, xcd, 7, 100, 0);
        }
        assert_eq!(hbm.stats().requests, 8);
        assert_eq!(hbm.stats().bytes_read, 800);
    }

    #[test]
    fn bandwidth_is_shared_fifo() {
        let mut hbm = HbmModel::new(100, 0);
        hbm.request(0, 0, 1, 100, 0);
        hbm.request(0, 1, 2, 100, 0);
        let d0 = hbm.step(0);
        assert_eq!(d0.len(), 1);
        assert_eq!(d0[0].key, 1);
        let d1 = hbm.step(1);
        assert_eq!(d1.len(), 1);
        assert_eq!(d1[0].key, 2);
    }

    #[test]
    fn several_small_requests_one_tick() {
        let mut hbm = HbmModel::new(1000, 0);
        for k in 0..5 {
            hbm.request(0, 0, k, 100, 0);
        }
        let done = hbm.step(0);
        assert_eq!(done.len(), 5);
    }

    #[test]
    fn backlog_ticks_estimates_drain_time() {
        let mut hbm = HbmModel::new(100, 0);
        for k in 0..10 {
            hbm.request(0, 0, k, 100, 0);
        }
        assert_eq!(hbm.backlog_ticks(), 10);
        hbm.step(0);
        assert_eq!(hbm.backlog_ticks(), 9);
    }

    #[test]
    fn writes_drain_with_leftover_budget() {
        let mut hbm = HbmModel::new(100, 0);
        hbm.write(150);
        hbm.request(0, 0, 1, 50, 0);
        hbm.step(0); // 50 read + 50 write
        assert_eq!(hbm.backlog_ticks(), 1); // 100 write bytes left
        hbm.step(1);
        assert_eq!(hbm.backlog_ticks(), 0);
        assert_eq!(hbm.stats().bytes_written, 150);
    }

    #[test]
    fn refetch_after_completion_is_new_request() {
        let mut hbm = HbmModel::new(1000, 0);
        hbm.request(0, 0, 9, 100, 0);
        hbm.step(0);
        hbm.request(1, 0, 9, 100, 0);
        assert_eq!(hbm.stats().requests, 2);
        assert_eq!(hbm.stats().mshr_merges, 0);
    }
}
