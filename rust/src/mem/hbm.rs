//! Bandwidth-budgeted HBM queue with MSHR merging.

use std::collections::VecDeque;

use crate::util::fxhash::FastMap;

/// Opaque handle identifying an outstanding request.
pub type RequestId = u64;

#[derive(Debug, Clone)]
struct Request {
    id: RequestId,
    /// XCD whose L2 will be filled.
    xcd: u32,
    /// Tile key being fetched.
    key: u64,
    /// Bytes remaining to transfer.
    remaining: u64,
    /// Total bytes of the tile (for the completion record).
    bytes: u32,
    /// Tick at which fixed latency has elapsed and transfer may begin.
    ready_at: u64,
}

/// One MSHR file entry: the in-flight fetch of an (xcd, key) pair plus
/// the workgroup slots waiting for it. Keeping the waiter list here (one
/// hash probe per issue/join) instead of in a separate engine-side map
/// (which cost a second probe per issue plus a third at completion) is
/// the hot-path de-hashing of DESIGN.md §13.
#[derive(Debug, Clone)]
struct Mshr {
    id: RequestId,
    /// Slot that first issued the fetch (hit/miss attribution).
    origin: u32,
    /// Slots to wake when the fill lands, in registration order.
    waiters: Vec<u32>,
}

/// A finished fill, to be inserted into `xcd`'s L2, carrying the slots
/// registered to be woken by it (in registration order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Completion {
    /// The request's id.
    pub id: RequestId,
    /// XCD whose L2 requested the fill.
    pub xcd: u32,
    /// Tile key being filled.
    pub key: u64,
    /// Fill size in bytes.
    pub bytes: u32,
    /// Slots that joined the fetch via [`HbmModel::fetch`].
    pub waiters: Vec<u32>,
}

/// How a [`HbmModel::fetch`] call was satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchKind {
    /// No fetch was in flight: a new HBM request was issued.
    Started,
    /// Joined an in-flight fetch this same slot issued earlier (a
    /// prefetch that has not landed yet — a miss the prefetch failed to
    /// hide; the miss was counted at issue time).
    MergedOwn,
    /// Joined an in-flight fetch issued by a DIFFERENT slot: true
    /// inter-workgroup sharing, counted as an L2 hit by the engine.
    MergedShared,
}

/// Aggregate traffic statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HbmStats {
    /// Total bytes transferred from HBM.
    pub bytes_read: u64,
    /// Demand requests issued (post-MSHR-merge).
    pub requests: u64,
    /// Requests absorbed by an in-flight MSHR (same XCD + tile).
    pub mshr_merges: u64,
    /// Ticks during which the queue was non-empty (utilization proxy).
    pub busy_ticks: u64,
    /// Sum of queue depth sampled each busy tick (avg depth = /busy_ticks).
    pub queue_depth_sum: u64,
    /// Write traffic (outputs), accounted against bandwidth.
    pub bytes_written: u64,
}

impl HbmStats {
    /// Mean queue depth over the run (contention indicator).
    pub fn avg_queue_depth(&self) -> f64 {
        if self.busy_ticks == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.busy_ticks as f64
        }
    }
}

/// The HBM model. Drive it with `request` / `fetch` / `write` and call
/// `step` once per simulator tick; completions wake waiting workgroups.
/// An event-driven caller can ask [`HbmModel::next_completion_tick`] for
/// the next tick on which `step` would deliver a fill and bulk-advance
/// the completion-free gap with [`HbmModel::skip_to`].
#[derive(Debug, Clone)]
pub struct HbmModel {
    /// Bytes the memory system can deliver per tick (device aggregate).
    bytes_per_tick: u64,
    /// Fixed access latency in ticks before a request starts transferring.
    latency_ticks: u64,
    queue: VecDeque<Request>,
    /// (xcd, key) -> in-flight fetch + its waiter list (the MSHR file).
    inflight: FastMap<(u32, u64), Mshr>,
    next_id: RequestId,
    /// Pending write bytes (drained at the same budget, lower priority).
    write_backlog: u64,
    stats: HbmStats,
}

impl HbmModel {
    /// An idle HBM model with the given bandwidth and base latency.
    pub fn new(bytes_per_tick: u64, latency_ticks: u64) -> Self {
        assert!(bytes_per_tick > 0);
        HbmModel {
            bytes_per_tick,
            latency_ticks,
            queue: VecDeque::new(),
            inflight: FastMap::default(),
            next_id: 0,
            write_backlog: 0,
            stats: HbmStats::default(),
        }
    }

    /// Accumulated traffic statistics.
    pub fn stats(&self) -> &HbmStats {
        &self.stats
    }

    /// The modeled bandwidth budget per tick.
    pub fn bytes_per_tick(&self) -> u64 {
        self.bytes_per_tick
    }

    /// Outstanding demand requests (post-merge).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Estimated ticks to drain the current backlog — the queue-delay
    /// feedback the simulator uses for stall accounting.
    pub fn backlog_ticks(&self) -> u64 {
        let bytes: u64 =
            self.queue.iter().map(|r| r.remaining).sum::<u64>() + self.write_backlog;
        bytes.div_ceil(self.bytes_per_tick)
    }

    /// Is a fetch of (xcd, key) already outstanding?
    pub fn is_inflight(&self, xcd: u32, key: u64) -> bool {
        self.inflight.contains_key(&(xcd, key))
    }

    /// Slot that first issued the outstanding fetch of (xcd, key), if any.
    /// A demand that merges into ANOTHER slot's fetch is true inter-WG
    /// sharing (counted as an L2 hit by the engine); merging into one's
    /// own still-pending prefetch is a miss the prefetch failed to hide.
    pub fn inflight_origin(&self, xcd: u32, key: u64) -> Option<u32> {
        self.inflight.get(&(xcd, key)).map(|m| m.origin)
    }

    /// Issue a demand read of `key` (`bytes` wide) on behalf of `xcd`.
    /// Returns the request id; if an identical (xcd, key) fetch is already
    /// in flight the ids are equal (MSHR merge) and no new traffic is
    /// generated. Registers no waiter — see [`HbmModel::fetch`] for the
    /// issue-or-join entry point the engine uses.
    pub fn request(&mut self, now: u64, xcd: u32, key: u64, bytes: u32, origin: u32) -> RequestId {
        if let Some(m) = self.inflight.get(&(xcd, key)) {
            self.stats.mshr_merges += 1;
            return m.id;
        }
        let id = self.enqueue(now, xcd, key, bytes);
        self.inflight.insert((xcd, key), Mshr { id, origin, waiters: Vec::new() });
        id
    }

    /// Issue-or-join: the engine's single entry point for a tile access
    /// that was not an L2 hit. One hash probe classifies the access
    /// (fresh fetch / own in-flight prefetch / another slot's fetch),
    /// registers `slot` to be woken by the completion, and — when no
    /// fetch is in flight — enqueues the HBM request.
    pub fn fetch(&mut self, now: u64, xcd: u32, key: u64, bytes: u32, slot: u32) -> FetchKind {
        use std::collections::hash_map::Entry;
        match self.inflight.entry((xcd, key)) {
            Entry::Occupied(mut e) => {
                let m = e.get_mut();
                m.waiters.push(slot);
                if m.origin == slot {
                    FetchKind::MergedOwn
                } else {
                    FetchKind::MergedShared
                }
            }
            Entry::Vacant(v) => {
                // Mirror `enqueue` inline: the vacant entry borrows the
                // map, but the queue/stats fields are disjoint.
                let id = self.next_id;
                self.next_id += 1;
                self.queue.push_back(Request {
                    id,
                    xcd,
                    key,
                    remaining: bytes as u64,
                    bytes,
                    ready_at: now + self.latency_ticks,
                });
                self.stats.requests += 1;
                self.stats.bytes_read += bytes as u64;
                v.insert(Mshr { id, origin: slot, waiters: vec![slot] });
                FetchKind::Started
            }
        }
    }

    fn enqueue(&mut self, now: u64, xcd: u32, key: u64, bytes: u32) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            xcd,
            key,
            remaining: bytes as u64,
            bytes,
            ready_at: now + self.latency_ticks,
        });
        self.stats.requests += 1;
        self.stats.bytes_read += bytes as u64;
        id
    }

    /// Account output (store) traffic. Writes contend for the same budget
    /// but never stall a workgroup directly (write-back, fire and forget).
    pub fn write(&mut self, bytes: u64) {
        self.write_backlog += bytes;
        self.stats.bytes_written += bytes;
    }

    /// Advance one tick: spend the bandwidth budget on queued reads
    /// (FIFO), then leftover budget on the write backlog. Returns the
    /// fills completed this tick, each carrying its registered waiters.
    pub fn step(&mut self, now: u64) -> Vec<Completion> {
        let mut completions = Vec::new();
        if self.queue.is_empty() && self.write_backlog == 0 {
            return completions;
        }
        self.stats.busy_ticks += 1;
        self.stats.queue_depth_sum += self.queue.len() as u64;

        let mut budget = self.bytes_per_tick;
        while budget > 0 {
            let Some(front) = self.queue.front_mut() else { break };
            if front.ready_at > now {
                // Head-of-line latency not yet elapsed; model simple
                // in-order service (no bypass) for determinism.
                break;
            }
            let take = front.remaining.min(budget);
            front.remaining -= take;
            budget -= take;
            if front.remaining == 0 {
                let r = self.queue.pop_front().unwrap();
                let waiters = self
                    .inflight
                    .remove(&(r.xcd, r.key))
                    .map(|m| m.waiters)
                    .unwrap_or_default();
                completions.push(Completion {
                    id: r.id,
                    xcd: r.xcd,
                    key: r.key,
                    bytes: r.bytes,
                    waiters,
                });
            }
        }
        // Drain writes with leftover budget.
        let wtake = self.write_backlog.min(budget);
        self.write_backlog -= wtake;
        completions
    }

    /// The earliest tick `t >= now` at which [`HbmModel::step`] would
    /// deliver a completion, or `None` when the read queue is empty.
    /// Exact under FIFO head-of-line service: the head transfers alone at
    /// the full per-tick budget once `now` passes its latency.
    pub fn next_completion_tick(&self, now: u64) -> Option<u64> {
        let front = self.queue.front()?;
        let start = now.max(front.ready_at);
        // `remaining` is always > 0 for a queued request.
        let ticks = front.remaining.div_ceil(self.bytes_per_tick);
        Some(start + ticks - 1)
    }

    /// Bulk-advance over the completion-free gap `[now, target)`: exactly
    /// what calling `step(t)` for each tick would have done — busy-tick
    /// and queue-depth accounting, head-of-line transfer progress, and
    /// write-backlog drain — without iterating tick by tick. The caller
    /// must guarantee no completion lands before `target`
    /// (`next_completion_tick(now) >= target`) and must not interleave
    /// `request`/`fetch`/`write` calls inside the gap.
    pub fn skip_to(&mut self, now: u64, target: u64) {
        if let Some(c) = self.next_completion_tick(now) {
            debug_assert!(c >= target, "skip_to({now}, {target}) would skip a completion at {c}");
        }
        let mut t = now;
        while t < target {
            if self.queue.is_empty() && self.write_backlog == 0 {
                return; // idle for the rest of the gap
            }
            let gap = target - t;
            let depth = self.queue.len() as u64;
            if let Some(front) = self.queue.front_mut() {
                if front.ready_at > t {
                    // Latency stall: reads idle, the full budget drains
                    // writes each tick (maximal per-tick drain makes the
                    // cumulative drain min(backlog, budget * dt)).
                    let dt = gap.min(front.ready_at - t);
                    self.stats.busy_ticks += dt;
                    self.stats.queue_depth_sum += depth * dt;
                    let w = self.write_backlog.min(self.bytes_per_tick.saturating_mul(dt));
                    self.write_backlog -= w;
                    t += dt;
                } else {
                    // Transferring: the whole budget goes to the head
                    // every tick (no leftover, so writes do not drain).
                    // No completion before `target` implies the head has
                    // strictly more than budget * gap bytes left.
                    let dt = gap;
                    let take = self.bytes_per_tick.saturating_mul(dt);
                    debug_assert!(front.remaining > take);
                    front.remaining -= take;
                    self.stats.busy_ticks += dt;
                    self.stats.queue_depth_sum += depth * dt;
                    t += dt;
                }
            } else {
                // Writes only: busy while backlog remains at tick entry.
                let drain_ticks = self.write_backlog.div_ceil(self.bytes_per_tick);
                self.stats.busy_ticks += gap.min(drain_ticks);
                let w = self.write_backlog.min(self.bytes_per_tick.saturating_mul(gap));
                self.write_backlog -= w;
                t += gap;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_request_completes_after_latency_and_transfer() {
        let mut hbm = HbmModel::new(100, 2);
        hbm.request(0, 0, 42, 250, 0);
        assert!(hbm.step(0).is_empty()); // latency
        assert!(hbm.step(1).is_empty()); // latency
        assert!(hbm.step(2).is_empty()); // 100/250
        assert!(hbm.step(3).is_empty()); // 200/250
        let done = hbm.step(4); // 250/250
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].key, 42);
        assert_eq!(done[0].bytes, 250);
    }

    #[test]
    fn mshr_merges_same_xcd_same_key() {
        let mut hbm = HbmModel::new(100, 0);
        let a = hbm.request(0, 3, 7, 100, 0);
        let b = hbm.request(0, 3, 7, 100, 0);
        assert_eq!(a, b);
        assert_eq!(hbm.stats().requests, 1);
        assert_eq!(hbm.stats().mshr_merges, 1);
        assert_eq!(hbm.stats().bytes_read, 100);
    }

    #[test]
    fn no_merge_across_xcds_models_replication_traffic() {
        // The Naive Head-first pathology: 8 XCDs all fetch the same tile.
        let mut hbm = HbmModel::new(1000, 0);
        for xcd in 0..8 {
            hbm.request(0, xcd, 7, 100, 0);
        }
        assert_eq!(hbm.stats().requests, 8);
        assert_eq!(hbm.stats().bytes_read, 800);
    }

    #[test]
    fn bandwidth_is_shared_fifo() {
        let mut hbm = HbmModel::new(100, 0);
        hbm.request(0, 0, 1, 100, 0);
        hbm.request(0, 1, 2, 100, 0);
        let d0 = hbm.step(0);
        assert_eq!(d0.len(), 1);
        assert_eq!(d0[0].key, 1);
        let d1 = hbm.step(1);
        assert_eq!(d1.len(), 1);
        assert_eq!(d1[0].key, 2);
    }

    #[test]
    fn several_small_requests_one_tick() {
        let mut hbm = HbmModel::new(1000, 0);
        for k in 0..5 {
            hbm.request(0, 0, k, 100, 0);
        }
        let done = hbm.step(0);
        assert_eq!(done.len(), 5);
    }

    #[test]
    fn backlog_ticks_estimates_drain_time() {
        let mut hbm = HbmModel::new(100, 0);
        for k in 0..10 {
            hbm.request(0, 0, k, 100, 0);
        }
        assert_eq!(hbm.backlog_ticks(), 10);
        hbm.step(0);
        assert_eq!(hbm.backlog_ticks(), 9);
    }

    #[test]
    fn writes_drain_with_leftover_budget() {
        let mut hbm = HbmModel::new(100, 0);
        hbm.write(150);
        hbm.request(0, 0, 1, 50, 0);
        hbm.step(0); // 50 read + 50 write
        assert_eq!(hbm.backlog_ticks(), 1); // 100 write bytes left
        hbm.step(1);
        assert_eq!(hbm.backlog_ticks(), 0);
        assert_eq!(hbm.stats().bytes_written, 150);
    }

    #[test]
    fn refetch_after_completion_is_new_request() {
        let mut hbm = HbmModel::new(1000, 0);
        hbm.request(0, 0, 9, 100, 0);
        hbm.step(0);
        hbm.request(1, 0, 9, 100, 0);
        assert_eq!(hbm.stats().requests, 2);
        assert_eq!(hbm.stats().mshr_merges, 0);
    }

    #[test]
    fn fetch_issues_then_joins_and_delivers_waiters_in_order() {
        let mut hbm = HbmModel::new(1000, 0);
        assert_eq!(hbm.fetch(0, 2, 7, 100, 5), FetchKind::Started);
        assert_eq!(hbm.fetch(0, 2, 7, 100, 5), FetchKind::MergedOwn);
        assert_eq!(hbm.fetch(0, 2, 7, 100, 9), FetchKind::MergedShared);
        // Joins generate no new traffic and no merge stat (the engine
        // attributes sharing in the L2 stats instead).
        assert_eq!(hbm.stats().requests, 1);
        assert_eq!(hbm.stats().mshr_merges, 0);
        assert_eq!(hbm.stats().bytes_read, 100);
        let done = hbm.step(0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].waiters, vec![5, 5, 9]);
        // After completion the MSHR entry is gone: a refetch starts anew.
        assert_eq!(hbm.fetch(1, 2, 7, 100, 9), FetchKind::Started);
    }

    #[test]
    fn next_completion_tick_accounts_latency_and_transfer() {
        let mut hbm = HbmModel::new(100, 2);
        assert_eq!(hbm.next_completion_tick(0), None);
        hbm.request(0, 0, 1, 250, 0); // ready at 2, 3 transfer ticks
        assert_eq!(hbm.next_completion_tick(0), Some(4));
        assert_eq!(hbm.next_completion_tick(3), Some(5)); // stalled caller
        // One-budget request completes the tick it becomes ready.
        let mut hbm = HbmModel::new(100, 5);
        hbm.request(0, 0, 1, 100, 0);
        assert_eq!(hbm.next_completion_tick(0), Some(5));
    }

    #[test]
    fn skip_to_matches_tick_by_tick_stepping() {
        // Differential: skipping a completion-free gap must leave the
        // model in exactly the state per-tick stepping produces —
        // including busy/depth statistics and the write backlog.
        let mut a = HbmModel::new(100, 4);
        a.request(0, 0, 1, 1000, 0); // completes at 4 + 9 = 13
        a.request(0, 1, 2, 300, 0);
        a.write(250);
        let mut b = a.clone();
        let next = a.next_completion_tick(0).unwrap();
        assert_eq!(next, 13);
        a.skip_to(0, next);
        for t in 0..next {
            assert!(b.step(t).is_empty(), "unexpected completion at {t}");
        }
        assert_eq!(a.stats(), b.stats());
        assert_eq!(a.backlog_ticks(), b.backlog_ticks());
        // Both deliver the same completion on the event tick.
        assert_eq!(a.step(next), b.step(next));
    }

    #[test]
    fn skip_to_drains_writes_and_goes_idle() {
        let mut a = HbmModel::new(100, 0);
        a.write(450); // 5 busy ticks to drain
        let mut b = a.clone();
        a.skip_to(0, 1000);
        for t in 0..1000 {
            b.step(t);
        }
        assert_eq!(a.stats(), b.stats());
        assert_eq!(a.stats().busy_ticks, 5);
        assert_eq!(a.backlog_ticks(), 0);
    }
}
