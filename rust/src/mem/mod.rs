//! HBM memory system model: a bandwidth-budgeted request queue shared by
//! all XCDs, with per-XCD MSHR merging.
//!
//! This is where the NUMA *traffic* costs of the paper materialize:
//! * every L2 miss becomes an HBM request;
//! * requests for the same tile from the *same* XCD are merged (MSHRs),
//!   so lockstep workgroups sharing a stream cost one fetch;
//! * requests for the same tile from *different* XCDs are NOT merged —
//!   that is the replication traffic of Naive Head-first (Fig. 9), where
//!   all eight dies stream identical K/V;
//! * the queue drains at the topology's bandwidth budget per tick, so
//!   miss storms (block-first thrash, Fig. 13's ~1% hit rates) saturate
//!   the queue and stall compute — the 50% performance loss of Fig. 12.

pub mod hbm;
pub mod kvpool;

pub use hbm::{Completion, FetchKind, HbmModel, HbmStats, RequestId};
pub use kvpool::{block_bytes, prompt_keys, Acquire, KvPool};
