//! Lightweight metrics: counters, latency histograms, and the table
//! formatter the figure generators use to print paper-style rows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic event counter, shareable across threads (`&self` API).
/// The simulation driver's report cache exposes its hit/miss totals
/// through these; the serving layer can adopt them incrementally.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter.
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    /// Add 1; returns the new total.
    pub fn inc(&self) -> u64 {
        self.add(1)
    }

    /// Add `n`; returns the new total.
    pub fn add(&self, n: u64) -> u64 {
        self.0.fetch_add(n, Ordering::Relaxed) + n
    }

    /// Current count.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-boundary latency histogram (power-of-two microsecond buckets).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Maximum recorded latency in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile from bucket upper bounds (q in 0..=1).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }
}

/// Nearest-rank percentile of a sample set, `q` in `[0, 1]`.
///
/// The exact rule (the "nearest-rank" method — **no interpolation**
/// between samples; the result is always one of the inputs):
///
/// 1. sort a copy ascending with the IEEE-754 total order, so the result
///    is deterministic for any input order (the serving report's TPOT
///    p50/p99 go through this) and NaN-bearing inputs still order;
/// 2. take the sample at rank `clamp(ceil(q · n), 1, n)` (1-based).
///
/// Consequences worth knowing at the edges:
/// * empty slice → `0.0` (the one case where the result is not a
///   sample). This is **frozen**: historical serving goldens bake the
///   `0.0` into their JSON, so it must not change to `NaN` here.
///   Callers that want "no samples" to *render* as `n/a`/`null` instead
///   of a fake zero (the fault windows' per-window rates, for example)
///   check for emptiness themselves and carry a `NaN` sentinel that
///   their own rendering maps to `n/a` (tables) or `null` (JSON);
/// * single sample → that sample for every `q`;
/// * `q = 0` (and any `q` with `q·n ≤ 1`) → the minimum, because the
///   rank clamps up to 1 — so "p0" is the smallest sample, not an
///   extrapolation below it;
/// * `q = 1` (p100) → the maximum, and values of `q > 1` also clamp to
///   it;
/// * even-sized sets have no "middle average": `percentile(&[1.0, 2.0],
///   0.5)` is `1.0` (rank `ceil(0.5·2) = 1`), not `1.5`.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
    v[rank - 1]
}

/// Markdown/console table builder for figure output.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Rows appended so far.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment (console) — also valid Markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts_across_threads() {
        let c = std::sync::Arc::new(Counter::new());
        assert_eq!(c.get(), 0);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 400);
        assert_eq!(c.add(10), 410);
    }

    #[test]
    fn histogram_basics() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 230.0).abs() < 1.0);
        assert_eq!(h.max_us(), 1000);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 0.99), 5.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // Input order never matters (total-order sort).
        let mut r = v;
        r.reverse();
        assert_eq!(percentile(&r, 0.5), percentile(&v, 0.5));
    }

    #[test]
    fn percentile_edge_cases_pin_the_documented_rule() {
        // Empty slice: 0.0, the one non-sample result.
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(percentile(&[], q), 0.0);
        }
        // Single sample: that sample at every quantile (rank clamps to 1).
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&[7.5], q), 7.5);
        }
        // p0 is the minimum (rank clamps UP to 1), p100 the maximum —
        // and an out-of-range q clamps rather than indexing out.
        let v = [10.0, -3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), -3.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
        assert_eq!(percentile(&v, 1.5), 10.0);
        // Nearest rank means NO interpolation: the even-sized median is
        // the lower of the two middle samples, never their average.
        assert_eq!(percentile(&[1.0, 2.0], 0.5), 1.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.5), 2.0);
        // Every non-empty result is one of the inputs.
        for q in [0.0, 0.1, 0.33, 0.5, 0.77, 0.99, 1.0] {
            let p = percentile(&v, q);
            assert!(v.contains(&p), "q={q}: {p} is not a sample");
        }
    }

    #[test]
    fn table_render_markdown() {
        let mut t = Table::new(&["config", "NBF", "SHF"]);
        t.row(vec!["H=128 N=128K".into(), "0.65".into(), "1.00".into()]);
        let s = t.render();
        assert!(s.contains("| config"));
        assert!(s.contains("| 0.65"));
        assert!(s.lines().count() == 3);
        assert!(s.lines().nth(1).unwrap().starts_with("|--") || s.lines().nth(1).unwrap().starts_with("|-"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
