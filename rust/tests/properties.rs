//! Property-based tests (hand-rolled generator over `util::rng` — the
//! proptest crate is unavailable offline; each property runs hundreds of
//! randomized cases from a fixed seed, printing the failing case on
//! violation).

use numa_attn::attn::acc::AccSpread;
use numa_attn::attn::trace::WgCursor;
use numa_attn::attn::{AttnConfig, KernelKind, WorkItem};
use numa_attn::cache::LruCache;
use numa_attn::cluster::{PoolKind, ShardPlan, ShardStrategy};
use numa_attn::coordinator::{SessionRouter, SloQueue};
use numa_attn::mapping::{chiplet_swizzle, Mapping, Policy, ALL_POLICIES};
use numa_attn::mem::KvPool;
use numa_attn::sched::{xcd_of_slot, Dispatcher};
use numa_attn::util::rng::SplitMix64;
use numa_attn::workload::{Session, SloClass};

fn policies(rng: &mut SplitMix64) -> Policy {
    ALL_POLICIES[rng.gen_range(4) as usize]
}

/// Random grid geometry with heads divisible by xcds (paper configs).
fn geometry(rng: &mut SplitMix64) -> (usize, usize, usize, usize) {
    let xcds = [2usize, 4, 8][rng.gen_range(3) as usize];
    let heads = xcds * (1 + rng.gen_range(16) as usize);
    let blocks = 1 + rng.gen_range(64) as usize;
    let batch = 1 + rng.gen_range(4) as usize;
    (batch, heads, blocks, xcds)
}

#[test]
fn prop_mapping_bijective() {
    let mut rng = SplitMix64::new(101);
    for case in 0..300 {
        let (b, h, nb, x) = geometry(&mut rng);
        let p = policies(&mut rng);
        let m = Mapping::new(p, b, h, nb, x).unwrap();
        let mut seen = vec![false; m.grid_size()];
        for s in 0..m.grid_size() {
            let w = m.decode(s);
            let idx = ((w.z as usize * h) + w.h as usize) * nb + w.b as usize;
            assert!(!seen[idx], "case {case}: duplicate work {w:?} ({p}, {b}x{h}x{nb}/{x})");
            seen[idx] = true;
        }
    }
}

#[test]
fn prop_shf_never_splits_a_head() {
    let mut rng = SplitMix64::new(202);
    for case in 0..200 {
        let (b, h, nb, x) = geometry(&mut rng);
        let m = Mapping::new(Policy::SwizzledHeadFirst, b, h, nb, x).unwrap();
        let mut head_xcd = vec![None; b * h];
        for s in 0..m.grid_size() {
            let w = m.decode(s);
            let xcd = xcd_of_slot(s, 1, x);
            let key = w.z as usize * h + w.h as usize;
            match head_xcd[key] {
                None => head_xcd[key] = Some(xcd),
                Some(prev) => assert_eq!(
                    prev, xcd,
                    "case {case}: head {} split across XCDs ({b}x{h}x{nb}/{x})",
                    w.h
                ),
            }
        }
    }
}

#[test]
fn prop_decode_mapping_bijective() {
    // Every policy is a bijection dispatch-slot <-> (batch, head, split)
    // on the flash-decode grid, for arbitrary split counts (including
    // splits that don't divide the column blocks or the XCD count).
    let mut rng = SplitMix64::new(909);
    for case in 0..200 {
        let (b, h, _, x) = geometry(&mut rng);
        let splits = 1 + rng.gen_range(16) as usize;
        let p = policies(&mut rng);
        let cfg = AttnConfig::mha(b, h, 128 * 32, 64);
        let kernel = KernelKind::DecodeSplitKv { num_splits: splits };
        let m = Mapping::for_kernel(p, &cfg, kernel, x).unwrap();
        assert_eq!(m.grid_size(), b * h * splits);
        let mut seen = vec![false; m.grid_size()];
        for s in 0..m.grid_size() {
            let w = m.decode(s);
            assert!((w.b as usize) < splits, "split out of range");
            let idx = ((w.z as usize * h) + w.h as usize) * splits + w.b as usize;
            assert!(!seen[idx], "case {case}: duplicate {w:?} ({p}, {b}x{h}x{splits}/{x})");
            seen[idx] = true;
        }
    }
}

#[test]
fn prop_shf_decode_splits_never_leave_their_xcd() {
    // SwizzledHeadFirst on the decode grid with chunk = 1 dispatch: all
    // splits of one (batch, head) — hence all of its partial results —
    // land on a single XCD.
    let mut rng = SplitMix64::new(1010);
    for case in 0..200 {
        let (b, h, _, x) = geometry(&mut rng);
        let splits = 1 + rng.gen_range(16) as usize;
        let cfg = AttnConfig::mha(b, h, 128 * 32, 64);
        let kernel = KernelKind::DecodeSplitKv { num_splits: splits };
        let m = Mapping::for_kernel(Policy::SwizzledHeadFirst, &cfg, kernel, x).unwrap();
        let mut head_xcd = vec![None; b * h];
        for s in 0..m.grid_size() {
            let w = m.decode(s);
            let xcd = xcd_of_slot(s, 1, x);
            let key = w.z as usize * h + w.h as usize;
            match head_xcd[key] {
                None => head_xcd[key] = Some(xcd),
                Some(prev) => assert_eq!(
                    prev, xcd,
                    "case {case}: head {} split {} left its XCD ({b}x{h}x{splits}/{x})",
                    w.h, w.b
                ),
            }
        }
    }
}

#[test]
fn prop_shard_plan_is_a_bijection_over_query_heads() {
    // The cluster analogue of prop_mapping_bijective (docs/CLUSTER.md):
    // for any GQA geometry and any TP degree dividing H_K, under both
    // strategies, each of the H_Q query heads lands on EXACTLY one
    // device, and the partition is balanced (H_Q/tp heads per device).
    let mut rng = SplitMix64::new(1111);
    for case in 0..300 {
        let tp = [1usize, 2, 4, 8][rng.gen_range(4) as usize];
        let h_k = tp * (1 + rng.gen_range(8) as usize);
        let group = 1 + rng.gen_range(8) as usize;
        let h_q = h_k * group;
        let strategies = [ShardStrategy::Contiguous, ShardStrategy::Strided];
        let strategy = strategies[rng.gen_range(2) as usize];
        let cfg = AttnConfig::gqa(1, h_q, h_k, 4096, 64);
        let plan = ShardPlan::new(&cfg, tp, strategy).unwrap();
        let mut owners = vec![0usize; h_q];
        for d in 0..tp {
            let heads = plan.query_heads(d);
            assert_eq!(
                heads.len(),
                h_q / tp,
                "case {case}: unbalanced shard ({strategy}, h_q={h_q}, tp={tp})"
            );
            for h in heads {
                owners[h] += 1;
                assert_eq!(plan.device_of_query_head(h), d, "case {case}: ownership disagrees");
            }
        }
        assert!(
            owners.iter().all(|&n| n == 1),
            "case {case}: not a bijection ({strategy}, h_q={h_q}, h_k={h_k}, tp={tp}): {owners:?}"
        );
    }
}

#[test]
fn prop_shard_plan_never_straddles_a_gqa_group() {
    // KV heads are never split: every query head of a KV group lives on
    // that KV head's device, so no device ever needs a remote KV cache
    // slice — the invariant that makes head sharding communication-free
    // until the output all-gather.
    let mut rng = SplitMix64::new(2222);
    for case in 0..300 {
        let tp = [1usize, 2, 4, 8][rng.gen_range(4) as usize];
        let h_k = tp * (1 + rng.gen_range(8) as usize);
        let group = 1 + rng.gen_range(8) as usize;
        let h_q = h_k * group;
        let strategy =
            [ShardStrategy::Contiguous, ShardStrategy::Strided][rng.gen_range(2) as usize];
        let cfg = AttnConfig::gqa(1, h_q, h_k, 4096, 64);
        let plan = ShardPlan::new(&cfg, tp, strategy).unwrap();
        for k in 0..h_k {
            let dev = plan.device_of_kv_head(k);
            for h in k * group..(k + 1) * group {
                assert_eq!(
                    plan.device_of_query_head(h),
                    dev,
                    "case {case}: query head {h} left KV head {k}'s device \
                     ({strategy}, h_q={h_q}, h_k={h_k}, tp={tp})"
                );
            }
        }
        // The shard-local geometry stays a valid GQA config with the
        // same group size — level 2 (the paper's mapping) sees a smaller
        // but shape-identical problem.
        let local = plan.local_attn(&cfg);
        local.validate().unwrap();
        assert_eq!(local.group(), cfg.group());
    }
}

#[test]
fn prop_sbf_gqa_groups_colocated_when_groups_eq_xcds() {
    // Paper Sec. 4.4: SBF co-locates ACCs exactly when H_K == num XCDs.
    let mut rng = SplitMix64::new(303);
    for _ in 0..100 {
        let x = [2usize, 4, 8][rng.gen_range(3) as usize];
        let h_k = x;
        let group = 1 + rng.gen_range(8) as usize;
        let h_q = h_k * group;
        if h_q % x != 0 {
            continue;
        }
        let nb = 1 + rng.gen_range(32) as usize;
        let cfg = AttnConfig::gqa(1, h_q, h_k, nb * 128, 128);
        let m = Mapping::new(Policy::SwizzledBlockFirst, 1, h_q, nb, x).unwrap();
        let spread = AccSpread::measure(
            &cfg,
            x,
            (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, x))),
        );
        assert!(spread.perfectly_colocated(), "h_q={h_q} h_k={h_k} x={x} nb={nb}");
        assert_eq!(spread.max_accs_per_xcd(), 1);
    }
}

#[test]
fn prop_chiplet_swizzle_bijective_when_divisible() {
    let mut rng = SplitMix64::new(404);
    for _ in 0..200 {
        let x = [2usize, 4, 8][rng.gen_range(3) as usize];
        let grid = x * (1 + rng.gen_range(256) as usize);
        let mut seen = vec![false; grid];
        for s in 0..grid {
            let l = chiplet_swizzle(s, grid, x);
            assert!(l < grid);
            assert!(!seen[l], "grid {grid} x {x}");
            seen[l] = true;
        }
    }
}

#[test]
fn prop_dispatcher_covers_grid_for_any_chunk() {
    let mut rng = SplitMix64::new(505);
    for _ in 0..100 {
        let (b, h, nb, x) = geometry(&mut rng);
        let chunk = 1 + rng.gen_range(4) as usize;
        let p = policies(&mut rng);
        let m = Mapping::new(p, b, h, nb, x).unwrap();
        let grid = m.grid_size();
        let mut d = Dispatcher::new(m, chunk, x);
        let mut count = 0usize;
        let mut seen = std::collections::HashSet::new();
        loop {
            let mut any = false;
            for xcd in 0..x as u32 {
                if let Some((slot, w)) = d.next_for_xcd(xcd) {
                    assert_eq!(xcd_of_slot(slot, chunk, x), xcd);
                    assert!(seen.insert((w.z, w.h, w.b)));
                    count += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(count, grid);
    }
}

#[test]
fn prop_lru_never_exceeds_capacity_and_counts_consistently() {
    let mut rng = SplitMix64::new(606);
    for _ in 0..50 {
        let cap = 1024 * (1 + rng.gen_range(64));
        let mut c = LruCache::new(cap);
        let key_space = 1 + rng.gen_range(200);
        let mut ops = 0u64;
        for _ in 0..2000 {
            let key = rng.gen_range(key_space);
            let bytes = (64 * (1 + rng.gen_range(8))) as u32;
            c.access(key, bytes);
            ops += 1;
            assert!(c.used_bytes() <= cap, "over capacity");
        }
        let s = c.stats();
        assert_eq!(s.hits + s.misses, ops);
        assert_eq!(s.hit_bytes + s.miss_bytes, s.hit_bytes + s.miss_bytes);
    }
}

/// Naive, obviously-correct LRU reference: `BTreeMap` for contents,
/// `VecDeque` (front = MRU) for recency — the oracle the slab+intrusive-
/// list `LruCache` (and its single-probe access path) is checked against.
struct NaiveLru {
    cap: u64,
    used: u64,
    entries: std::collections::BTreeMap<u64, u32>,
    order: std::collections::VecDeque<u64>,
    hits: u64,
    misses: u64,
    evictions: u64,
    hit_bytes: u64,
    miss_bytes: u64,
}

impl NaiveLru {
    fn new(cap: u64) -> Self {
        NaiveLru {
            cap,
            used: 0,
            entries: Default::default(),
            order: Default::default(),
            hits: 0,
            misses: 0,
            evictions: 0,
            hit_bytes: 0,
            miss_bytes: 0,
        }
    }

    fn touch(&mut self, key: u64) {
        let pos = self.order.iter().position(|&k| k == key).unwrap();
        self.order.remove(pos);
        self.order.push_front(key);
    }

    fn insert_absent(&mut self, key: u64, bytes: u32) {
        if bytes as u64 > self.cap {
            return; // oversized entries stream through
        }
        while self.used + bytes as u64 > self.cap {
            let lru = self.order.pop_back().unwrap();
            let b = self.entries.remove(&lru).unwrap();
            self.used -= b as u64;
            self.evictions += 1;
        }
        self.order.push_front(key);
        self.entries.insert(key, bytes);
        self.used += bytes as u64;
    }

    fn hit(&mut self, key: u64, bytes: u32) {
        self.hits += 1;
        self.hit_bytes += bytes as u64;
        self.touch(key);
    }

    fn access(&mut self, key: u64, bytes: u32) -> bool {
        if self.entries.contains_key(&key) {
            self.hit(key, bytes);
            true
        } else {
            self.misses += 1;
            self.miss_bytes += bytes as u64;
            self.insert_absent(key, bytes);
            false
        }
    }

    fn probe(&mut self, key: u64, bytes: u32) -> bool {
        if self.entries.contains_key(&key) {
            self.hit(key, bytes);
            true
        } else {
            self.misses += 1;
            self.miss_bytes += bytes as u64;
            false
        }
    }

    fn try_hit(&mut self, key: u64, bytes: u32) -> bool {
        if self.entries.contains_key(&key) {
            self.hit(key, bytes);
            true
        } else {
            false
        }
    }

    fn fill(&mut self, key: u64, bytes: u32) {
        if self.entries.contains_key(&key) {
            self.touch(key);
        } else {
            self.insert_absent(key, bytes);
        }
    }

    fn invalidate(&mut self, key: u64) -> bool {
        if let Some(b) = self.entries.remove(&key) {
            let pos = self.order.iter().position(|&k| k == key).unwrap();
            self.order.remove(pos);
            self.used -= b as u64;
            true
        } else {
            false
        }
    }
}

#[test]
fn prop_lru_matches_naive_reference_model() {
    // 10k mixed access/probe/try_hit/fill/invalidate ops per seed. The
    // key space (96 keys x up to 512 B) deliberately straddles the
    // capacity range so runs mix hit-heavy, eviction-heavy, and
    // oversized-entry regimes. After every op: same return value and
    // same used_bytes; at the end: identical stats and identical full
    // MRU -> LRU order.
    for seed in [11u64, 22, 33, 44, 55] {
        let mut rng = SplitMix64::new(seed);
        let cap = 1024 * (1 + rng.gen_range(16));
        let mut real = LruCache::new(cap);
        let mut model = NaiveLru::new(cap);
        for op in 0..10_000u32 {
            let key = rng.gen_range(96);
            let bytes = (64 * (1 + rng.gen_range(8))) as u32;
            let ctx = format!("seed {seed} op {op} key {key} bytes {bytes} cap {cap}");
            match rng.gen_range(5) {
                0 => assert_eq!(real.access(key, bytes), model.access(key, bytes), "{ctx}"),
                1 => assert_eq!(real.probe(key, bytes), model.probe(key, bytes), "{ctx}"),
                2 => assert_eq!(real.try_hit(key, bytes), model.try_hit(key, bytes), "{ctx}"),
                3 => {
                    real.fill(key, bytes);
                    model.fill(key, bytes);
                }
                _ => {
                    assert_eq!(real.invalidate(key), model.invalidate(key), "{ctx}");
                }
            }
            assert_eq!(real.used_bytes(), model.used, "{ctx}");
            assert_eq!(real.len(), model.entries.len(), "{ctx}");
        }
        let s = real.stats();
        assert_eq!(s.hits, model.hits, "seed {seed}");
        assert_eq!(s.misses, model.misses, "seed {seed}");
        assert_eq!(s.evictions, model.evictions, "seed {seed}");
        assert_eq!(s.hit_bytes, model.hit_bytes, "seed {seed}");
        assert_eq!(s.miss_bytes, model.miss_bytes, "seed {seed}");
        let order: Vec<u64> = model.order.iter().copied().collect();
        assert_eq!(real.keys_mru_to_lru(), order, "seed {seed}: MRU order");
    }
}

#[test]
fn prop_lru_no_evict_stats_match_model_within_capacity() {
    // The analytic fast path's contract: as long as the total distinct
    // working set fits, set_no_evict(true) must leave every statistic
    // identical to the honest LRU (only the unobservable recency order
    // differs). Keys x bytes are drawn so the sum always fits.
    for seed in [7u64, 77, 777] {
        let mut rng = SplitMix64::new(seed);
        let keys = 1 + rng.gen_range(32);
        let bytes = 128u32;
        let cap = keys * bytes as u64; // exact fit
        let mut fast = LruCache::new(cap);
        fast.set_no_evict(true);
        let mut model = NaiveLru::new(cap);
        for _ in 0..10_000u32 {
            let key = rng.gen_range(keys);
            match rng.gen_range(4) {
                0 => {
                    assert_eq!(fast.access(key, bytes), model.access(key, bytes));
                }
                1 => {
                    assert_eq!(fast.probe(key, bytes), model.probe(key, bytes));
                }
                2 => {
                    assert_eq!(fast.try_hit(key, bytes), model.try_hit(key, bytes));
                }
                _ => {
                    fast.fill(key, bytes);
                    model.fill(key, bytes);
                }
            }
        }
        let s = fast.stats();
        assert_eq!(s.hits, model.hits, "seed {seed}");
        assert_eq!(s.misses, model.misses, "seed {seed}");
        assert_eq!(s.hit_bytes, model.hit_bytes, "seed {seed}");
        assert_eq!(s.miss_bytes, model.miss_bytes, "seed {seed}");
        assert_eq!(s.evictions, 0, "seed {seed}: no_evict must never evict");
        assert_eq!(fast.used_bytes(), model.used, "seed {seed}");
    }
}

#[test]
fn prop_causal_streams_monotonic_in_block() {
    // Forward: later row blocks see >= K/V tiles; dK/dV: later column
    // blocks see <= row blocks.
    let mut rng = SplitMix64::new(707);
    for _ in 0..100 {
        let blocks_m = 1 + rng.gen_range(16) as usize;
        let cfg = AttnConfig {
            causal: true,
            ..AttnConfig::mha(1, 4, blocks_m * 128, 64)
        };
        let mut prev = 0;
        for b in 0..cfg.num_row_blocks() {
            let cur = WgCursor::new(&cfg, KernelKind::Forward, WorkItem { z: 0, h: 0, b: b as u32 });
            assert!(cur.stream_len() >= prev);
            prev = cur.stream_len();
        }
        let mut prev = u32::MAX;
        for b in 0..cfg.num_col_blocks() {
            let cur = WgCursor::new(&cfg, KernelKind::BwdDkDv, WorkItem { z: 0, h: 0, b: b as u32 });
            assert!(cur.stream_len() <= prev);
            prev = cur.stream_len();
        }
    }
}

#[test]
fn prop_trace_flops_match_totals() {
    // Summing per-step flops over every WG must equal the closed form.
    let mut rng = SplitMix64::new(808);
    for _ in 0..30 {
        let h = 1 + rng.gen_range(4) as usize;
        let nb = 1 + rng.gen_range(8) as usize;
        let causal = rng.gen_range(2) == 0;
        let cfg = AttnConfig { causal, ..AttnConfig::mha(1, h, nb * 128, 64) };
        let mut total = 0.0f64;
        for hh in 0..h as u32 {
            for b in 0..cfg.num_row_blocks() as u32 {
                let mut cur = WgCursor::new(&cfg, KernelKind::Forward, WorkItem { z: 0, h: hh, b });
                while let Some(s) = cur.next_step() {
                    total += s.flops;
                }
            }
        }
        if !causal {
            let expected = cfg.total_fwd_flops();
            assert!((total - expected).abs() / expected < 1e-9, "{total} vs {expected}");
        } else {
            // Causal tile count over-covers the exact N^2/2 a bit
            // (diagonal blocks are full tiles); bounded above by full.
            assert!(total >= cfg.total_fwd_flops() * 0.99);
            assert!(total <= cfg.total_fwd_flops() * 2.0 + 1.0);
        }
    }
}

/// Naive, obviously-correct paged-KV reference: each resident block is
/// its FULL key prefix in a `BTreeMap` (no trie, no slab, no free
/// list), leases are full prefix paths, and eviction re-derives
/// "refcount-0 childless" by scanning for one-longer resident prefixes.
/// The oracle `mem::KvPool`'s trie is checked against, op for op.
struct NaiveKvPool {
    /// Capacity in blocks (`usize::MAX` = unlimited).
    cap_blocks: usize,
    blocks: NaiveKvBlocks,
    leases: std::collections::BTreeMap<u64, Vec<Vec<u64>>>,
    clock: u64,
    next_insert: u64,
    evictions: u64,
    hits: u64,
    misses: u64,
}

struct NaiveKvBlock {
    refs: usize,
    last_use: u64,
    insert_id: u64,
}

type NaiveKvBlocks = std::collections::BTreeMap<Vec<u64>, NaiveKvBlock>;

fn naive_childless(blocks: &NaiveKvBlocks, p: &[u64]) -> bool {
    !blocks.keys().any(|q| q.len() == p.len() + 1 && q[..p.len()] == *p)
}

impl NaiveKvPool {
    fn new(cap_blocks: usize) -> Self {
        NaiveKvPool {
            cap_blocks,
            blocks: Default::default(),
            leases: Default::default(),
            clock: 0,
            next_insert: 0,
            evictions: 0,
            hits: 0,
            misses: 0,
        }
    }

    fn make_room(&mut self) -> bool {
        if self.cap_blocks == 0 {
            return false;
        }
        while self.blocks.len() + 1 > self.cap_blocks {
            let victim = self
                .blocks
                .iter()
                .filter(|(p, b)| b.refs == 0 && naive_childless(&self.blocks, p))
                .min_by_key(|(_, b)| (b.last_use, b.insert_id))
                .map(|(p, _)| p.clone());
            let Some(p) = victim else { return false };
            self.blocks.remove(&p);
            self.evictions += 1;
        }
        true
    }

    fn acquire(&mut self, session: u64, keys: &[u64]) -> (usize, Vec<usize>) {
        assert!(!self.leases.contains_key(&session), "model: double acquire");
        self.clock += 1;
        let clock = self.clock;
        let mut path: Vec<Vec<u64>> = Vec::new();
        let mut credited = 0usize;
        let mut inserted = Vec::new();
        let mut walking = true;
        for j in 0..keys.len() {
            let prefix = keys[..=j].to_vec();
            if walking {
                if let Some(b) = self.blocks.get_mut(&prefix) {
                    b.refs += 1;
                    b.last_use = clock;
                    path.push(prefix);
                    credited += 1;
                    self.hits += 1;
                    continue;
                }
                walking = false;
            }
            self.misses += 1;
            if !self.make_room() {
                break;
            }
            let block = NaiveKvBlock { refs: 1, last_use: clock, insert_id: self.next_insert };
            self.next_insert += 1;
            self.blocks.insert(prefix.clone(), block);
            path.push(prefix);
            inserted.push(j);
        }
        self.leases.insert(session, path);
        (credited, inserted)
    }

    fn release(&mut self, session: u64) {
        let Some(path) = self.leases.remove(&session) else { return };
        for p in path {
            self.blocks.get_mut(&p).expect("model: leased block resident").refs -= 1;
        }
    }

    fn probe(&self, keys: &[u64]) -> usize {
        let mut run = 0;
        for j in 0..keys.len() {
            if self.blocks.contains_key(&keys[..=j]) {
                run += 1;
            } else {
                break;
            }
        }
        run
    }

    fn total_refs(&self) -> usize {
        self.blocks.values().map(|b| b.refs).sum()
    }
}

#[test]
fn prop_kvpool_matches_naive_full_prefix_model() {
    // 10k mixed acquire/release/probe ops per seed against the
    // full-prefix oracle. Chains reuse prefixes of earlier chains 3/4 of
    // the time (the cross-session hit and copy-on-write fork regimes)
    // over a 5-symbol key alphabet; capacities from 0 (unlimited) to 12
    // blocks straddle hit-heavy, eviction-heavy, and budget-starved
    // regimes. After every op: identical credited/inserted answers,
    // identical used-bytes and resident-block accounting, refcount
    // conservation (sum of refcounts == sum of lease lengths), the byte
    // budget holds, and every live lease's full path is still resident
    // (no live block was evicted).
    const BB: u64 = 1024;
    for seed in [13u64, 26, 39, 52, 65] {
        let mut rng = SplitMix64::new(seed);
        let cap_blocks = rng.gen_range(13) as usize; // 0 = unlimited
        let mut pool = KvPool::new(BB, cap_blocks as u64 * BB);
        let cap = if cap_blocks == 0 { usize::MAX } else { cap_blocks };
        let mut model = NaiveKvPool::new(cap);
        let mut chains: Vec<Vec<u64>> = Vec::new();
        let mut live: Vec<u64> = Vec::new();
        let mut next_session = 0u64;
        for op in 0..10_000u32 {
            // A fresh chain, usually forked off a prefix of an old one.
            let len = 1 + rng.gen_range(6) as usize;
            let mut chain: Vec<u64> = Vec::new();
            if !chains.is_empty() && rng.gen_range(4) != 0 {
                let base = &chains[rng.gen_range(chains.len() as u64) as usize];
                let take = 1 + rng.gen_range(base.len() as u64) as usize;
                chain.extend_from_slice(&base[..take]);
            }
            chain.truncate(len);
            while chain.len() < len {
                chain.push(1 + rng.gen_range(5));
            }
            let ctx = format!("seed {seed} op {op} cap {cap_blocks} chain {chain:?}");
            match rng.gen_range(4) {
                0 | 1 => {
                    let sid = next_session;
                    next_session += 1;
                    let got = pool.acquire(sid, &chain);
                    let (credited, inserted) = model.acquire(sid, &chain);
                    assert_eq!(got.credited_blocks, credited, "{ctx}");
                    assert_eq!(got.inserted, inserted, "{ctx}");
                    live.push(sid);
                    if chains.len() < 256 {
                        chains.push(chain);
                    }
                }
                2 if !live.is_empty() => {
                    let at = rng.gen_range(live.len() as u64) as usize;
                    let sid = live.swap_remove(at);
                    pool.release(sid);
                    model.release(sid);
                }
                _ => {
                    assert_eq!(pool.probe(&chain), model.probe(&chain), "{ctx}");
                }
            }
            assert_eq!(pool.used_bytes(), model.blocks.len() as u64 * BB, "{ctx}");
            assert_eq!(pool.resident_blocks(), model.blocks.len(), "{ctx}");
            assert_eq!(pool.total_refs(), pool.leased_blocks(), "{ctx}: conservation");
            assert_eq!(pool.total_refs(), model.total_refs(), "{ctx}");
            assert_eq!(pool.leased_blocks(), model.leases.values().map(Vec::len).sum(), "{ctx}");
            if cap_blocks > 0 {
                assert!(pool.used_bytes() <= pool.capacity_bytes(), "{ctx}: over budget");
            }
            for (sid, path) in &model.leases {
                if let Some(deepest) = path.last() {
                    assert_eq!(
                        pool.probe(deepest),
                        path.len(),
                        "{ctx}: session {sid}'s live lease lost a block"
                    );
                }
            }
        }
        let (hits, misses) = pool.hit_miss_blocks();
        assert_eq!(hits, model.hits, "seed {seed}");
        assert_eq!(misses, model.misses, "seed {seed}");
        assert_eq!(pool.evictions(), model.evictions, "seed {seed}");
        assert!(pool.peak_used_bytes() >= pool.used_bytes(), "seed {seed}");
    }
}

/// A random serving session — arbitrary fields, because the router
/// property is exactly that it ignores them all.
fn random_session(rng: &mut SplitMix64, id: u64) -> Session {
    Session {
        id,
        arrival_sec: rng.next_f64() * 10.0,
        prefill: 1 + rng.gen_range(8192) as usize,
        decode_tokens: 1 + rng.gen_range(256) as usize,
        shared_prefix: rng.gen_range(2048) as usize,
        slo: if rng.gen_range(2) == 0 { SloClass::Interactive } else { SloClass::Batch },
    }
}

#[test]
fn prop_session_route_is_total_function_of_shape() {
    // The disagg router's contract (docs/DISAGG.md §3): pool assignment
    // is a total function of (session, deployment shape). Re-routing the
    // same sessions under ANY arrival interleaving — and with any field
    // values — yields identical per-session routes.
    let mut rng = SplitMix64::new(4242);
    for case in 0..200 {
        let disagg = rng.gen_range(2) == 0;
        let router = SessionRouter::new(disagg);
        assert_eq!(router.disaggregated(), disagg);
        let want = if disagg {
            (PoolKind::Prefill, PoolKind::Decode)
        } else {
            (PoolKind::Decode, PoolKind::Decode)
        };
        let n = 1 + rng.gen_range(32) as usize;
        let mut sessions: Vec<Session> =
            (0..n).map(|i| random_session(&mut rng, i as u64)).collect();
        let baseline: Vec<(u64, _)> = sessions.iter().map(|s| (s.id, router.route(s))).collect();
        for (id, r) in &baseline {
            assert_eq!((r.prefill, r.decode), want, "case {case} session {id}");
        }
        // Shuffle the interleaving (Fisher-Yates) and re-route: every
        // session's route must be byte-identical to its baseline.
        for i in (1..sessions.len()).rev() {
            let j = rng.gen_range((i + 1) as u64) as usize;
            sessions.swap(i, j);
        }
        for s in &sessions {
            let base = baseline.iter().find(|(id, _)| *id == s.id).unwrap().1;
            assert_eq!(router.route(s), base, "case {case}: route depends on interleaving");
        }
    }
}

#[test]
fn prop_slo_queue_matches_sorted_vector_model() {
    // Differential pin of the SLO admission queue (interactive first,
    // then earliest arrival, then lowest id) against a naive
    // sorted-vector priority model: 10k randomized push/pop ops per
    // seed, with every pop, peek, and length compared exactly, then a
    // full drain.
    let key = |s: &Session| (s.slo.rank(), s.arrival_sec.to_bits(), s.id);
    for seed in [13u64, 26, 39, 52, 65] {
        let mut rng = SplitMix64::new(seed);
        let mut q = SloQueue::new();
        let mut model: Vec<Session> = Vec::new();
        let mut next_id = 0u64;
        let pop_best = |model: &mut Vec<Session>| -> Session {
            let at = model
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| key(s))
                .map(|(i, _)| i)
                .expect("model non-empty");
            model.remove(at)
        };
        for op in 0..10_000 {
            if q.is_empty() || rng.gen_range(5) < 3 {
                let s = random_session(&mut rng, next_id);
                next_id += 1;
                q.push(s.clone());
                model.push(s);
            } else {
                let got = q.pop().expect("queue non-empty");
                let want = pop_best(&mut model);
                assert_eq!(key(&got), key(&want), "seed {seed} op {op}: pop order diverged");
            }
            assert_eq!(q.len(), model.len(), "seed {seed} op {op}");
            assert_eq!(q.is_empty(), model.is_empty(), "seed {seed} op {op}");
            let want_peek = model.iter().min_by_key(|s| key(s)).map(key);
            assert_eq!(
                q.peek().map(key),
                want_peek,
                "seed {seed} op {op}: peek diverged"
            );
        }
        while let Some(got) = q.pop() {
            let want = pop_best(&mut model);
            assert_eq!(key(&got), key(&want), "seed {seed}: drain order diverged");
        }
        assert!(model.is_empty(), "seed {seed}: model must drain with the queue");
    }
}

#[test]
fn policy_name_display_fromstr_roundtrip() {
    // Every policy round-trips through all three textual forms:
    // `name()`, `Display`, and the short CLI alias.
    for p in ALL_POLICIES {
        assert_eq!(p.name().parse::<Policy>().unwrap(), p, "name() round-trip");
        assert_eq!(p.to_string().parse::<Policy>().unwrap(), p, "Display round-trip");
        assert_eq!(format!("{p}"), p.name(), "Display renders name()");
        let alias: String = p
            .name()
            .split('_')
            .map(|w| w.chars().next().unwrap())
            .collect();
        assert_eq!(alias.parse::<Policy>().unwrap(), p, "short alias {alias}");
        assert!(!p.label().is_empty());
    }
    assert!("not_a_policy".parse::<Policy>().is_err());
}
