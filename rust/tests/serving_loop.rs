//! Contracts of the continuous-batching decode serving loop
//! (docs/SERVING.md):
//!
//! * the serving report is *byte-identical* at any driver worker count
//!   (the `serve` analogue of tests/driver_determinism.rs) — with
//!   chunked prefill off AND on;
//! * golden equivalence of the chunked-prefill refactor: `chunk_tokens
//!   = 0` runs the historical monolithic path (pinned across worker
//!   counts), and `chunk_tokens >= max prompt` degenerates to one chunk
//!   whose serving stats reproduce the monolithic JSON byte-for-byte;
//! * SwizzledHeadFirst's decode throughput is at least NaiveHeadFirst's
//!   (the paper's mapping win, measured end-to-end through the loop);
//! * `pick_num_splits` is monotone the way the loop relies on: once a
//!   session's KV length is in the serving regime, growing past further
//!   bucket boundaries never *increases* the split count (it is pinned
//!   by the device-fill target, not the KV length), and a growing batch
//!   only ever shrinks it.

use numa_attn::attn::AttnConfig;
use numa_attn::coordinator::{
    pick_num_splits, serve_decode_disagg_with, serve_decode_with, DisaggConfig, ServeConfig,
};
use numa_attn::driver::SimDriver;
use numa_attn::mapping::Policy;
use numa_attn::topology::{presets, Topology};
use numa_attn::workload::{TraceReplay, TraceSpec};

/// Scaled-down MI300X (same shape as the advisor's unit-test topology)
/// so the loop runs in test time.
fn fast_topo() -> Topology {
    Topology {
        cus_per_xcd: 8,
        l2_bytes_per_xcd: 1024 * 1024,
        hbm_bytes_per_sec: 1.1e12,
        ..presets::mi300x()
    }
}

fn small_serve() -> ServeConfig {
    ServeConfig {
        h_q: 16,
        h_k: 8,
        d_head: 64,
        kv_cap: 16384,
        kv_bucket: 2048,
        arrival_per_sec: 1000.0,
        prefill_lengths: vec![2040, 4096],
        decode_tokens: vec![8, 24],
        sessions: 8,
        max_active: 4,
        max_steps: 300,
        seed: 13,
        ..ServeConfig::default()
    }
}

#[test]
fn serve_json_is_byte_identical_at_threads_1_and_8() {
    let topo = fast_topo();
    let cfg = small_serve();
    for policy in [Policy::SwizzledHeadFirst, Policy::NaiveBlockFirst] {
        let serial = serve_decode_with(&SimDriver::new(1), &topo, &cfg, policy);
        let parallel = serve_decode_with(&SimDriver::new(8), &topo, &cfg, policy);
        assert_eq!(
            serial.to_json().render(),
            parallel.to_json().render(),
            "{policy} serve stats diverged between 1 and 8 workers"
        );
    }
}

#[test]
fn chunked_serve_json_is_byte_identical_at_threads_1_and_8() {
    // The determinism contract extends to mixed prefill+decode steps.
    let topo = fast_topo();
    let cfg = ServeConfig { chunk_tokens: 512, step_token_budget: 1024, ..small_serve() };
    for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
        let serial = serve_decode_with(&SimDriver::new(1), &topo, &cfg, policy);
        let parallel = serve_decode_with(&SimDriver::new(8), &topo, &cfg, policy);
        assert_eq!(
            serial.to_json().render(),
            parallel.to_json().render(),
            "{policy} chunked serve stats diverged between 1 and 8 workers"
        );
    }
}

#[test]
fn golden_whole_prompt_chunks_reproduce_monolithic_serve_byte_for_byte() {
    // The golden-equivalence pin of the chunked-prefill tentpole: a
    // chunk size covering every prompt in the mix degenerates to ONE
    // full-prompt chunk per session — the identical forward job at row
    // fraction 1.0 — so the whole serving report (throughput, TPOT,
    // TTFT, prefill accounting, advisor consults) must reproduce the
    // chunking-off run byte-for-byte, at 1 and 8 driver workers.
    let topo = fast_topo();
    let off = small_serve();
    let max_prompt = *off.prefill_lengths.iter().max().unwrap();
    let one_chunk = ServeConfig { chunk_tokens: max_prompt, ..small_serve() };
    for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
        for threads in [1usize, 8] {
            let mono = serve_decode_with(&SimDriver::new(threads), &topo, &off, policy);
            let chunked = serve_decode_with(&SimDriver::new(threads), &topo, &one_chunk, policy);
            assert_eq!(
                mono.to_json().render(),
                chunked.to_json().render(),
                "{policy} @ {threads} workers: one-chunk serve diverged from monolithic"
            );
        }
    }
}

#[test]
fn golden_sharing_disabled_reproduces_historical_serve_byte_for_byte() {
    // The golden-equivalence pin of the paged-KV tentpole
    // (docs/KVCACHE.md): the pool engages only when BOTH
    // `kv_block_tokens` and `prefix_share_pct` are non-zero, so either
    // knob at 0 must take the exact pre-pool code path and reproduce
    // the historical serving JSON byte-for-byte — at 1 and 8 driver
    // workers, under both step compositions.
    let topo = fast_topo();
    for chunk in [0usize, 512] {
        let base = ServeConfig { chunk_tokens: chunk, ..small_serve() };
        let blocks_only = ServeConfig { kv_block_tokens: 256, ..base.clone() };
        let share_only = ServeConfig { prefix_share_pct: 80.0, ..base.clone() };
        for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
            for threads in [1usize, 8] {
                let driver = SimDriver::new(threads);
                let want = serve_decode_with(&driver, &topo, &base, policy).to_json().render();
                for (name, cfg) in [("blocks_only", &blocks_only), ("share_only", &share_only)] {
                    assert!(!cfg.kv_pool_enabled(), "{name}: one knob must not enable the pool");
                    let got = serve_decode_with(&driver, &topo, cfg, policy).to_json().render();
                    assert_eq!(
                        got, want,
                        "{policy} @ {threads} workers chunk {chunk}: {name} diverged from \
                         the pool-free serve JSON"
                    );
                }
            }
        }
    }
}

#[test]
fn shared_serve_json_is_byte_identical_at_threads_1_and_8() {
    // Determinism extends to the pool-enabled paths: credited prompts,
    // suffix-chunk pricing, and the affinity stat are all priced through
    // the memoizing driver, so worker count must never leak into the
    // report.
    let topo = fast_topo();
    for chunk in [0usize, 512] {
        let cfg = ServeConfig {
            chunk_tokens: chunk,
            kv_block_tokens: 256,
            prefix_share_pct: 80.0,
            kv_capacity_mb: 64,
            ..small_serve()
        };
        let serial = serve_decode_with(&SimDriver::new(1), &topo, &cfg, Policy::SwizzledHeadFirst);
        let parallel =
            serve_decode_with(&SimDriver::new(8), &topo, &cfg, Policy::SwizzledHeadFirst);
        assert_eq!(
            serial.to_json().render(),
            parallel.to_json().render(),
            "chunk {chunk}: shared serve stats diverged between 1 and 8 workers"
        );
    }
}

#[test]
fn golden_colocated_disagg_reproduces_historical_serve_byte_for_byte() {
    // The disaggregation tentpole's golden pin (docs/DISAGG.md §2):
    // `prefill_devices = 0` means colocated, and with one decode device
    // the run takes the exact historical single-device serving path —
    // so the DisaggStats JSON (extras absent) must reproduce the
    // `serve` JSON byte-for-byte, at 1 and 8 driver workers, under both
    // step compositions. `interactive_pct` stays 0 so the trace is the
    // identical all-batch session stream.
    let topo = fast_topo();
    for (chunk, budget) in [(0usize, 0usize), (512, 1024)] {
        let base = ServeConfig { chunk_tokens: chunk, step_token_budget: budget, ..small_serve() };
        let cfg = DisaggConfig {
            serve: base.clone(),
            prefill_devices: 0,
            decode_devices: 1,
            interactive_pct: 0.0,
            ttft_slo_ms: 0.0,
            ..DisaggConfig::default()
        };
        assert!(cfg.colocated());
        for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
            for threads in [1usize, 8] {
                let driver = SimDriver::new(threads);
                let want = serve_decode_with(&driver, &topo, &base, policy).to_json().render();
                let got = serve_decode_disagg_with(&driver, &topo, &cfg, policy);
                assert!(got.extras.is_none(), "colocated run must not grow extras");
                assert_eq!(
                    got.to_json().render(),
                    want,
                    "{policy} @ {threads} workers chunk {chunk}: colocated disagg diverged \
                     from the historical serve JSON"
                );
            }
        }
    }
}

#[test]
fn chunked_serve_improves_the_first_token_tail() {
    // The tentpole's payoff at test scale: streaming prompts in
    // row-block chunks conserves every served token while cutting the
    // prefill wall-clock and the TTFT tail (one prompt no longer
    // freezes the decode streams of the step that admits it).
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let mono_cfg = small_serve();
    let chunked_cfg = ServeConfig { chunk_tokens: 512, step_token_budget: 1024, ..small_serve() };
    let mono = serve_decode_with(&driver, &topo, &mono_cfg, Policy::SwizzledHeadFirst);
    let chunked = serve_decode_with(&driver, &topo, &chunked_cfg, Policy::SwizzledHeadFirst);
    assert!(!mono.truncated && !chunked.truncated);
    assert_eq!(chunked.tokens, mono.tokens, "identical trace, identical tokens");
    assert_eq!(chunked.prefill_tokens, mono.prefill_tokens, "prompt-token conservation");
    assert!(
        chunked.prefill_sec < mono.prefill_sec,
        "chunked prefill {} s >= monolithic {} s",
        chunked.prefill_sec,
        mono.prefill_sec
    );
    assert!(
        chunked.ttft_p99_ms <= mono.ttft_p99_ms,
        "chunked TTFT p99 {} ms > monolithic {} ms",
        chunked.ttft_p99_ms,
        mono.ttft_p99_ms
    );
}

#[test]
fn serve_shf_throughput_at_least_nhf() {
    // The acceptance claim of the serving loop, at test scale: a
    // deployment configured with the paper's swizzled head-first mapping
    // serves decode tokens at least as fast as the naive head-first
    // Triton default, under the identical arrival trace. (The figure
    // and the serve_loop bench assert the same on the full MI300X
    // sweep.)
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let cfg = small_serve();
    let shf = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
    let nhf = serve_decode_with(&driver, &topo, &cfg, Policy::NaiveHeadFirst);
    assert_eq!(shf.tokens, nhf.tokens, "identical trace, identical token totals");
    assert!(!shf.truncated && !nhf.truncated);
    assert!(
        shf.tokens_per_sec >= nhf.tokens_per_sec,
        "SHF {} tok/s < NHF {} tok/s",
        shf.tokens_per_sec,
        nhf.tokens_per_sec
    );
    assert!(shf.tpot_p50_ms <= shf.tpot_p99_ms);
}

#[test]
fn prop_pick_num_splits_monotone_across_kv_buckets() {
    let topo = presets::mi300x();
    // (a) The serving-regime property the loop's re-advising relies on:
    // for every batch size, walking the KV length up through each bucket
    // boundary the loop uses (4K quantum here) never increases the split
    // count — past the device-fill point the choice is driven by
    // batch × heads against the WG slots, not by KV length, so decode
    // advice taken early in a session stays valid as its cache grows.
    for batch in [1usize, 2, 3, 4, 8] {
        let mut prev: Option<usize> = None;
        for kv in (1..=64).map(|i| i * 4096) {
            let cfg = AttnConfig::gqa(batch, 64, 8, kv, 128);
            let s = pick_num_splits(&topo, &cfg);
            assert!((1..=cfg.num_col_blocks()).contains(&s));
            if let Some(p) = prev {
                assert!(
                    s <= p,
                    "B={batch}: splits grew {p} -> {s} crossing the {kv}-token boundary"
                );
            }
            prev = Some(s);
        }
    }
    // (b) Below the serving regime the cap (one KV column block per
    // split) binds instead, and growth is monotone non-decreasing up to
    // the device-fill plateau — the two regimes meet at the plateau.
    let mut prev = 0usize;
    for kv in [128usize, 256, 512, 1024, 4096, 16384] {
        let cfg = AttnConfig::gqa(1, 64, 8, kv, 128);
        let s = pick_num_splits(&topo, &cfg);
        assert!(s >= prev, "cap-bound region must be non-decreasing ({prev} -> {s} at {kv})");
        prev = s;
    }
    // (c) A growing batch always needs the same or fewer splits.
    for kv in [16384usize, 65536] {
        let mut prev: Option<usize> = None;
        for batch in 1..=16 {
            let cfg = AttnConfig::gqa(batch, 64, 8, kv, 128);
            let s = pick_num_splits(&topo, &cfg);
            if let Some(p) = prev {
                assert!(s <= p, "N={kv}: splits grew {p} -> {s} at batch {batch}");
            }
            prev = Some(s);
        }
    }
}

#[test]
fn serve_step_budget_truncates_cleanly() {
    // A starved step budget must stop the loop, flag the run, and still
    // report internally-consistent counters.
    let driver = SimDriver::new(2);
    let topo = fast_topo();
    let cfg = ServeConfig { max_steps: 3, ..small_serve() };
    let s = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
    assert!(s.truncated);
    assert_eq!(s.steps, 3);
    assert!(s.sessions_completed < cfg.sessions);
    assert!(s.tokens <= (cfg.max_active * s.steps) as u64);
}

#[test]
fn golden_replayed_trace_reproduces_generated_trace_byte_for_byte() {
    // The .trace round-trip pin (docs/SERVING.md §8): rendering a
    // generated bursty schedule and parsing it back must reproduce the
    // identical session list — arrivals use shortest-round-trip f64
    // formatting — so the replayed serve renders JSON byte-identical
    // to the generated serve at 1 and 8 driver workers.
    let topo = fast_topo();
    let spec = TraceSpec {
        sessions: 8,
        prefill_lengths: vec![2040, 4096],
        decode_tokens: vec![8, 24],
        share_pct: 50.0,
        share_span: 1024,
        interactive_pct: 50.0,
        ..TraceSpec::default()
    };
    let generated = spec.generate();
    let replayed = TraceReplay::parse(&generated.render()).unwrap();
    assert_eq!(generated.render(), replayed.render(), "render/parse must round-trip");
    let gen_cfg = ServeConfig { trace: Some(generated), ..small_serve() };
    let rep_cfg = ServeConfig { trace: Some(replayed), ..small_serve() };
    for threads in [1usize, 8] {
        let driver = SimDriver::new(threads);
        let a = serve_decode_with(&driver, &topo, &gen_cfg, Policy::SwizzledHeadFirst);
        let b = serve_decode_with(&driver, &topo, &rep_cfg, Policy::SwizzledHeadFirst);
        assert_eq!(
            a.to_json().render(),
            b.to_json().render(),
            "{threads} workers: replayed trace diverged from the generated trace"
        );
    }
}

#[test]
fn golden_no_trace_config_is_untouched_by_the_trace_field() {
    // The trace plumbing must cost the historical generator path
    // nothing: `trace: None` (the default) renders the same serving
    // JSON as before the field existed, at 1 and 8 driver workers —
    // locked here so trace-threading refactors can't silently perturb
    // the seeded-generator golden.
    let topo = fast_topo();
    let cfg = small_serve();
    assert!(cfg.trace.is_none(), "small_serve must stay on the generator path");
    let serial = serve_decode_with(&SimDriver::new(1), &topo, &cfg, Policy::SwizzledHeadFirst);
    let parallel = serve_decode_with(&SimDriver::new(8), &topo, &cfg, Policy::SwizzledHeadFirst);
    assert_eq!(serial.to_json().render(), parallel.to_json().render());
    assert_eq!(serial.sessions_completed, cfg.sessions);
}
