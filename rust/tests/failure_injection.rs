//! Failure-injection tests, two layers deep:
//!
//! * artifact-level: corrupt artifacts, missing files, tampered
//!   goldens, and degenerate service configurations must fail loudly
//!   and precisely — never hang, never serve wrong numbers silently;
//! * cluster-level (docs/SERVING.md §9): the fault-injection grid —
//!   seed × fault plan × KV pool on/off — must conserve sessions and
//!   leases through every fail/recover cycle: no session lost, none
//!   double-served, every eviction paired with exactly one
//!   re-admission, and no pool lease still held when the run drains.

use std::fs;
use std::path::PathBuf;

use numa_attn::coordinator::{
    serve_decode_disagg_traced, serve_decode_faulty_traced, serve_decode_faulty_with,
    AttentionService, BatcherConfig, DisaggConfig, FaultEvent, FaultPlan, ServeConfig,
    ServiceConfig,
};
use numa_attn::driver::SimDriver;
use numa_attn::mapping::Policy;
use numa_attn::runtime::{Manifest, Runtime};
use numa_attn::topology::{presets, Topology};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Copy the real artifacts into a temp dir we can corrupt.
fn scratch_copy(name: &str) -> Option<PathBuf> {
    let src = artifact_dir()?;
    let dst = std::env::temp_dir().join(format!("numa-attn-fi-{name}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(&dst).unwrap();
    for entry in fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    Some(dst)
}

#[test]
fn missing_manifest_is_an_error() {
    let dir = std::env::temp_dir().join("numa-attn-empty");
    let _ = fs::create_dir_all(&dir);
    let err = Runtime::open(&dir).err().expect("must fail");
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
}

#[test]
fn corrupt_manifest_json_is_an_error() {
    let Some(dir) = scratch_copy("badjson") else { return };
    fs::write(dir.join("manifest.json"), "{ not json !!").unwrap();
    assert!(Runtime::open(&dir).is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn manifest_referencing_missing_hlo_file_fails_at_load() {
    let Some(dir) = scratch_copy("missinghlo") else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let victim = manifest.attention_artifacts().next().unwrap().clone();
    fs::remove_file(dir.join(&victim.file)).unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    let err = rt.load(&victim.name).unwrap_err();
    assert!(
        format!("{err:#}").contains(&victim.file) || format!("{err:#}").contains("HLO"),
        "{err:#}"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncated_hlo_text_fails_to_parse() {
    let Some(dir) = scratch_copy("trunc") else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let victim = manifest.attention_artifacts().next().unwrap().clone();
    let path = dir.join(&victim.file);
    let text = fs::read_to_string(&path).unwrap();
    fs::write(&path, &text[..text.len() / 3]).unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    assert!(rt.load(&victim.name).is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn tampered_golden_is_detected() {
    let Some(dir) = scratch_copy("golden") else { return };
    // Inflate every golden abs_sum by 10%: verify must fail.
    let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
    let tampered = regex_free_scale_abs_sums(&text);
    fs::write(dir.join("manifest.json"), tampered).unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    let name = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.golden.is_some())
        .unwrap()
        .name
        .clone();
    rt.load(&name).unwrap();
    let err = rt.verify(&name, 1e-3).unwrap_err();
    assert!(format!("{err:#}").contains("golden mismatch"), "{err:#}");
    let _ = fs::remove_dir_all(&dir);
}

/// Multiply every "abs_sum": <num> in the JSON by 1.1 without regex.
fn regex_free_scale_abs_sums(text: &str) -> String {
    let mut out = String::new();
    let mut rest = text;
    while let Some(pos) = rest.find("\"abs_sum\":") {
        let (head, tail) = rest.split_at(pos + "\"abs_sum\":".len());
        out.push_str(head);
        let end = tail
            .find(|c: char| c == ',' || c == '}')
            .expect("number terminator");
        let num: f64 = tail[..end].trim().parse().expect("abs_sum number");
        out.push_str(&format!(" {}", num * 1.1));
        rest = &tail[end..];
    }
    out.push_str(rest);
    out
}

#[test]
fn service_fails_fast_on_empty_catalogue() {
    let Some(dir) = scratch_copy("nocat") else { return };
    // Strip all attention artifacts from the manifest.
    let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
    let stripped = text.replace("\"attn_fwd\"", "\"attn_disabled\"");
    fs::write(dir.join("manifest.json"), stripped).unwrap();
    let err = AttentionService::start(ServiceConfig {
        artifact_dir: dir.clone(),
        batcher: BatcherConfig::default(),
    })
    .err()
    .expect("must fail");
    assert!(format!("{err:#}").contains("no batch-1 attention artifacts"), "{err:#}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn verify_on_artifact_without_golden_errors() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let name = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.golden.is_none())
        .map(|a| a.name.clone());
    if let Some(name) = name {
        rt.load(&name).unwrap();
        assert!(rt.verify(&name, 1e-3).is_err());
    }
}

// ---------------------------------------------------------------------
// Cluster fault-injection invariants (docs/SERVING.md §9)
// ---------------------------------------------------------------------

/// Scaled-down MI300X (same shape as tests/serving_loop.rs) so the
/// serving loops run in test time.
fn fast_topo() -> Topology {
    Topology {
        cus_per_xcd: 8,
        l2_bytes_per_xcd: 1024 * 1024,
        hbm_bytes_per_sec: 1.1e12,
        ..presets::mi300x()
    }
}

/// Decode-dominated serving config (near-simultaneous arrivals, short
/// prompts, long decode budgets): the run is a dense train of decode
/// steps, so mid-run outages are guaranteed to land on step boundaries
/// and fire. `pool` switches the paged KV pool (and with it the lease
/// machinery the grid audits) on.
fn fault_serve(seed: u64, pool: bool) -> ServeConfig {
    ServeConfig {
        h_q: 16,
        h_k: 8,
        d_head: 64,
        kv_cap: 16384,
        kv_bucket: 2048,
        arrival_per_sec: 1.0e6,
        prefill_lengths: vec![512],
        decode_tokens: vec![100],
        sessions: 6,
        max_active: 6,
        max_steps: 4000,
        seed,
        kv_block_tokens: if pool { 256 } else { 0 },
        prefix_share_pct: if pool { 50.0 } else { 0.0 },
        kv_capacity_mb: if pool { 1024 } else { 0 },
        ..ServeConfig::default()
    }
}

#[test]
fn fault_grid_no_session_lost_or_double_served() {
    // The invariant grid: seed × fault plan × KV pool. Each cell runs
    // the tp=2 faulty serving loop and audits the event log — the
    // exactly-once and lease-conservation contracts must hold whether
    // the outage is a mid-run single failure, staggered failures of
    // both devices, or a pre-arrival total blackout.
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let tp = 2;
    for seed in [7u64, 13] {
        for pool in [false, true] {
            let cfg = fault_serve(seed, pool);
            let clean = serve_decode_faulty_with(
                &driver,
                &topo,
                tp,
                &cfg,
                Policy::SwizzledHeadFirst,
                &FaultPlan::default(),
            );
            assert!(!clean.serve.truncated, "seed={seed} pool={pool}: clean run truncated");
            let t = clean.serve.sim_sec;
            let plans = [
                // One device drops across the middle of the serve.
                FaultPlan {
                    events: vec![FaultEvent {
                        device: 1,
                        fail_sec: 0.35 * t,
                        recover_sec: 0.65 * t,
                    }],
                },
                // Staggered outages hit both devices in turn.
                FaultPlan {
                    events: vec![
                        FaultEvent { device: 0, fail_sec: 0.2 * t, recover_sec: 0.4 * t },
                        FaultEvent { device: 1, fail_sec: 0.55 * t, recover_sec: 0.7 * t },
                    ],
                },
                // Total blackout before the first arrival.
                FaultPlan {
                    events: vec![
                        FaultEvent { device: 0, fail_sec: 0.0, recover_sec: 1e-7 },
                        FaultEvent { device: 1, fail_sec: 0.0, recover_sec: 2e-7 },
                    ],
                },
            ];
            for (pi, plan) in plans.iter().enumerate() {
                let tag = format!("seed={seed} pool={pool} plan#{pi}");
                let (stats, trace) = serve_decode_faulty_traced(
                    &driver,
                    &topo,
                    tp,
                    &cfg,
                    Policy::SwizzledHeadFirst,
                    plan,
                );
                let f = stats.faults.as_ref().expect("non-empty plan records extras");
                assert!(!stats.serve.truncated, "{tag}: faulty run truncated");
                // Every scheduled transition was applied.
                assert_eq!(f.events_applied, 2 * plan.events.len(), "{tag}");
                assert_eq!(trace.transitions.len(), f.events_applied, "{tag}");
                // No session lost, none double-served: ids 0..sessions
                // each retire exactly once.
                assert_eq!(stats.serve.sessions_completed, cfg.sessions, "{tag}");
                let mut completed = trace.completions.clone();
                completed.sort_unstable();
                assert_eq!(
                    completed,
                    (0..cfg.sessions as u64).collect::<Vec<_>>(),
                    "{tag}: a session was lost or double-served"
                );
                // Every eviction pairs with exactly one re-admission.
                for id in 0..cfg.sessions as u64 {
                    let admitted = trace.admissions.iter().filter(|&&a| a == id).count();
                    let evicted = trace.evictions.iter().filter(|&&e| e == id).count();
                    assert_eq!(admitted, 1 + evicted, "{tag}: session {id}");
                }
                assert_eq!(trace.evictions.len(), f.requeued, "{tag}");
                // Lease conservation: evictions force-release exactly
                // their leases, and nothing is still held at the end.
                assert_eq!(trace.leases_at_end, 0, "{tag}: a KV lease leaked");
                if pool {
                    assert_eq!(f.forced_releases, f.requeued, "{tag}");
                } else {
                    assert_eq!(f.forced_releases, 0, "{tag}");
                }
            }
        }
    }
}

#[test]
fn disagg_pool_split_grid_conserves_sessions_under_replayed_traces() {
    // The disaggregated half of the grid: seed × pool split, each cell
    // serving a replayed trace (docs/SERVING.md §8) through the
    // prefill/decode-split loop. Handoffs, completions, and per-step
    // audits must all conserve sessions — the trace machinery must not
    // open a path for a session to vanish between pools.
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    for seed in [7u64, 13] {
        for (prefill_devices, decode_devices) in [(1usize, 1usize), (1, 2)] {
            let tag = format!("seed={seed} split={prefill_devices}p/{decode_devices}d");
            let spec = numa_attn::workload::TraceSpec {
                seed,
                sessions: 6,
                prefill_lengths: vec![512, 2040],
                decode_tokens: vec![8, 24],
                interactive_pct: 50.0,
                ..numa_attn::workload::TraceSpec::default()
            };
            let generated = spec.generate();
            let replayed =
                numa_attn::workload::TraceReplay::parse(&generated.render()).unwrap();
            let cfg = DisaggConfig {
                serve: ServeConfig {
                    h_q: 16,
                    h_k: 8,
                    d_head: 64,
                    kv_cap: 16384,
                    kv_bucket: 2048,
                    sessions: 6,
                    max_active: 4,
                    max_steps: 2000,
                    seed,
                    trace: Some(replayed),
                    ..ServeConfig::default()
                },
                prefill_devices,
                decode_devices,
                interactive_pct: 50.0,
                ..DisaggConfig::default()
            };
            let (stats, trace) = serve_decode_disagg_traced(
                &driver,
                &topo,
                &cfg,
                Policy::SwizzledHeadFirst,
            );
            assert!(!stats.serve.truncated, "{tag}: run truncated");
            assert_eq!(stats.serve.sessions_completed, spec.sessions, "{tag}");
            assert_eq!(trace.sessions.len(), spec.sessions, "{tag}: trace rows served");
            // Disaggregated cells hand each session off exactly once.
            if prefill_devices > 0 {
                let mut handed: Vec<u64> = trace.handoffs.iter().map(|h| h.id).collect();
                handed.sort_unstable();
                assert_eq!(
                    handed,
                    (0..spec.sessions as u64).collect::<Vec<_>>(),
                    "{tag}: each session must hand off exactly once"
                );
            }
        }
    }
}
