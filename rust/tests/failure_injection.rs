//! Failure-injection tests: corrupt artifacts, missing files, tampered
//! goldens, and degenerate service configurations must fail loudly and
//! precisely — never hang, never serve wrong numbers silently.

use std::fs;
use std::path::PathBuf;

use numa_attn::coordinator::{AttentionService, BatcherConfig, ServiceConfig};
use numa_attn::runtime::{Manifest, Runtime};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Copy the real artifacts into a temp dir we can corrupt.
fn scratch_copy(name: &str) -> Option<PathBuf> {
    let src = artifact_dir()?;
    let dst = std::env::temp_dir().join(format!("numa-attn-fi-{name}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(&dst).unwrap();
    for entry in fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    Some(dst)
}

#[test]
fn missing_manifest_is_an_error() {
    let dir = std::env::temp_dir().join("numa-attn-empty");
    let _ = fs::create_dir_all(&dir);
    let err = Runtime::open(&dir).err().expect("must fail");
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
}

#[test]
fn corrupt_manifest_json_is_an_error() {
    let Some(dir) = scratch_copy("badjson") else { return };
    fs::write(dir.join("manifest.json"), "{ not json !!").unwrap();
    assert!(Runtime::open(&dir).is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn manifest_referencing_missing_hlo_file_fails_at_load() {
    let Some(dir) = scratch_copy("missinghlo") else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let victim = manifest.attention_artifacts().next().unwrap().clone();
    fs::remove_file(dir.join(&victim.file)).unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    let err = rt.load(&victim.name).unwrap_err();
    assert!(
        format!("{err:#}").contains(&victim.file) || format!("{err:#}").contains("HLO"),
        "{err:#}"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncated_hlo_text_fails_to_parse() {
    let Some(dir) = scratch_copy("trunc") else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let victim = manifest.attention_artifacts().next().unwrap().clone();
    let path = dir.join(&victim.file);
    let text = fs::read_to_string(&path).unwrap();
    fs::write(&path, &text[..text.len() / 3]).unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    assert!(rt.load(&victim.name).is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn tampered_golden_is_detected() {
    let Some(dir) = scratch_copy("golden") else { return };
    // Inflate every golden abs_sum by 10%: verify must fail.
    let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
    let tampered = regex_free_scale_abs_sums(&text);
    fs::write(dir.join("manifest.json"), tampered).unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    let name = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.golden.is_some())
        .unwrap()
        .name
        .clone();
    rt.load(&name).unwrap();
    let err = rt.verify(&name, 1e-3).unwrap_err();
    assert!(format!("{err:#}").contains("golden mismatch"), "{err:#}");
    let _ = fs::remove_dir_all(&dir);
}

/// Multiply every "abs_sum": <num> in the JSON by 1.1 without regex.
fn regex_free_scale_abs_sums(text: &str) -> String {
    let mut out = String::new();
    let mut rest = text;
    while let Some(pos) = rest.find("\"abs_sum\":") {
        let (head, tail) = rest.split_at(pos + "\"abs_sum\":".len());
        out.push_str(head);
        let end = tail
            .find(|c: char| c == ',' || c == '}')
            .expect("number terminator");
        let num: f64 = tail[..end].trim().parse().expect("abs_sum number");
        out.push_str(&format!(" {}", num * 1.1));
        rest = &tail[end..];
    }
    out.push_str(rest);
    out
}

#[test]
fn service_fails_fast_on_empty_catalogue() {
    let Some(dir) = scratch_copy("nocat") else { return };
    // Strip all attention artifacts from the manifest.
    let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
    let stripped = text.replace("\"attn_fwd\"", "\"attn_disabled\"");
    fs::write(dir.join("manifest.json"), stripped).unwrap();
    let err = AttentionService::start(ServiceConfig {
        artifact_dir: dir.clone(),
        batcher: BatcherConfig::default(),
    })
    .err()
    .expect("must fail");
    assert!(format!("{err:#}").contains("no batch-1 attention artifacts"), "{err:#}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn verify_on_artifact_without_golden_errors() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let name = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.golden.is_none())
        .map(|a| a.name.clone());
    if let Some(name) = name {
        rt.load(&name).unwrap();
        assert!(rt.verify(&name, 1e-3).is_err());
    }
}
