//! Runtime + coordinator integration over the real AOT artifacts.
//! Every test is skipped (with a notice) if `make artifacts` has not run —
//! they are exercised by `make test`, which builds artifacts first.

use std::path::PathBuf;
use std::time::Duration;

use numa_attn::coordinator::{AttentionService, BatcherConfig, ServiceConfig};
use numa_attn::runtime::{inputs, Runtime};
use numa_attn::workload::{Request, RequestGenerator};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn load_and_verify_all_golden_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load_all().unwrap();
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.golden.is_some())
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty());
    for n in names {
        let (got, want) = rt.verify(&n, 1e-3).unwrap();
        assert!((got - want).abs() / want < 1e-3, "{n}: {got} vs {want}");
    }
}

#[test]
fn attention_artifact_executes_with_custom_inputs() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let name = "attn_mha_z1_h8_n128_d64";
    rt.load(name).unwrap();
    let meta = rt.manifest().get(name).unwrap().clone();
    let qkv: Vec<Vec<f32>> = meta
        .inputs
        .iter()
        .enumerate()
        .map(|(i, spec)| inputs::det_input(100 + i as u64, spec.num_elements()))
        .collect();
    let r = rt.execute(name, &qkv).unwrap();
    assert_eq!(r.outputs.len(), 1);
    assert_eq!(r.outputs[0].len(), meta.outputs[0].num_elements());
    assert!(r.outputs[0].iter().all(|v| v.is_finite()));
    // Attention output is a convex combination of V rows: bounded by
    // max |v| (v values are in [-0.5, 0.5)).
    assert!(r.outputs[0].iter().all(|v| v.abs() <= 0.5 + 1e-4));
    // Same inputs -> identical outputs (deterministic execution).
    let r2 = rt.execute(name, &qkv).unwrap();
    assert_eq!(r.outputs[0], r2.outputs[0]);
}

#[test]
fn execute_rejects_wrong_arity_and_shape() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let name = "attn_mha_z1_h8_n128_d64";
    rt.load(name).unwrap();
    assert!(rt.execute(name, &[vec![0.0; 8]]).is_err());
    let bad = vec![vec![0.0f32; 7]; 3];
    assert!(rt.execute(name, &bad).is_err());
    assert!(rt.execute("nonexistent", &[]).is_err());
}

#[test]
fn service_serves_and_batches() {
    let Some(dir) = artifact_dir() else { return };
    let service = AttentionService::start(ServiceConfig {
        artifact_dir: dir,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
    })
    .unwrap();
    let lengths = service.router().bucket_lengths();
    assert!(!lengths.is_empty());
    let mut gen = RequestGenerator::new(5, lengths);
    let reqs = gen.take(16);
    let waiters: Vec<_> = reqs.iter().map(|r| service.submit(r.clone()).unwrap()).collect();
    for w in waiters {
        let resp = w.wait().unwrap();
        assert!(resp.checksum > 0.0);
        assert!(resp.batch_size >= 1);
    }
    let m = service.shutdown();
    assert_eq!(m.requests, 16);
    assert_eq!(m.errors, 0);
    assert!(m.batches >= 1);
}

#[test]
fn service_rejects_oversized_requests() {
    let Some(dir) = artifact_dir() else { return };
    let service = AttentionService::start(ServiceConfig {
        artifact_dir: dir,
        batcher: BatcherConfig::default(),
    })
    .unwrap();
    let too_long = Request { id: 0, n_ctx: 1 << 20, seed: 1 };
    assert!(service.submit(too_long).is_err());
}

#[test]
fn stacked_execution_checksums_match_singles() {
    // Two requests served through the batch-2 artifact must produce the
    // same per-request checksums as serving them alone (failure injection
    // for the stacking path).
    let Some(dir) = artifact_dir() else { return };
    let mk = |max_batch| {
        AttentionService::start(ServiceConfig {
            artifact_dir: dir.clone(),
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(30) },
        })
        .unwrap()
    };
    let reqs = vec![
        Request { id: 0, n_ctx: 256, seed: 1001 },
        Request { id: 1, n_ctx: 256, seed: 2002 },
    ];

    // Batched (stacked) run.
    let service = mk(2);
    let waiters: Vec<_> = reqs.iter().map(|r| service.submit(r.clone()).unwrap()).collect();
    let batched: Vec<f64> = waiters.into_iter().map(|w| w.wait().unwrap().checksum).collect();
    let m = service.shutdown();

    // Sequential singles.
    let service = mk(1);
    let mut single = Vec::new();
    for r in &reqs {
        single.push(service.submit(r.clone()).unwrap().wait().unwrap().checksum);
    }
    service.shutdown();

    for (b, s) in batched.iter().zip(&single) {
        assert!((b - s).abs() / s < 1e-5, "stacked {b} vs single {s}");
    }
    assert!(m.stacked_executions > 0, "batch-2 artifact was not used");
}
