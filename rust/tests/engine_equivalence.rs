//! Differential pin of the event-driven engine against the reference
//! per-tick-scan engine (DESIGN.md §13): across the golden forward /
//! backward / decode × all-policies matrix, every `SimReport` must render
//! to byte-identical JSON — on the serial driver AND the 8-worker pool.
//! This is the contract that lets every consumer (figures, advisor,
//! serving loop, cluster layer) run on the fast engine without any
//! behavioral drift: if an optimization in the event path changes a
//! single counter, this suite fails.

use numa_attn::attn::AttnConfig;
use numa_attn::driver::{SimDriver, SimJob};
use numa_attn::mapping::ALL_POLICIES;
use numa_attn::sim::{
    simulate_backward_reference, simulate_decode_reference, simulate_reference, SimConfig,
    SimReport,
};
use numa_attn::topology::{presets, Topology};
use numa_attn::workload::sweeps;

fn small_topo() -> Topology {
    Topology {
        name: "tiny".into(),
        num_xcds: 4,
        cus_per_xcd: 4,
        l2_bytes_per_xcd: 512 * 1024,
        ..presets::mi300x()
    }
}

/// The golden matrix (mirrors `driver_determinism.rs`): a small sweep ×
/// all 4 policies × forward/backward/decode = 36 jobs, each paired with
/// the reference engine's report for the same job. The decode jobs
/// include the reduce phase, whose tiny working set is exactly where the
/// event engine's analytic no-evict path fires — so this matrix pins the
/// fast path, not just the common one.
fn matrix() -> (Vec<SimJob>, Vec<SimReport>) {
    let topo = small_topo();
    let points = sweeps::mha_sensitivity(&[1024, 2048], &[1], &[4]);
    let extra = sweeps::backward_sweep(&[1024], &[1]);
    let mut jobs = Vec::new();
    let mut oracle = Vec::new();
    for pt in points.iter().chain(&extra) {
        let cfg = AttnConfig { block_m: 128, block_n: 64, h_q: 4, h_k: 4, ..pt.cfg };
        for &p in &ALL_POLICIES {
            let fwd = SimConfig::forward(p);
            jobs.push(SimJob::forward(&topo, &cfg, fwd));
            oracle.push(simulate_reference(&topo, &cfg, &fwd));
            let bwd = SimConfig::backward(p);
            jobs.push(SimJob::backward(&topo, &cfg, bwd));
            oracle.push(simulate_backward_reference(&topo, &cfg, &bwd));
            let dec = SimConfig::decode(p, 2);
            jobs.push(SimJob::decode(&topo, &cfg, dec));
            oracle.push(simulate_decode_reference(&topo, &cfg, &dec));
        }
    }
    (jobs, oracle)
}

#[test]
fn event_engine_byte_identical_to_reference_at_1_and_8_threads() {
    let (jobs, oracle) = matrix();
    assert_eq!(jobs.len(), oracle.len());
    let serial = SimDriver::new(1).run_all(jobs.clone());
    let parallel = SimDriver::new(8).run_all(jobs);
    for (i, want) in oracle.iter().enumerate() {
        let want = want.to_json().render();
        assert_eq!(
            serial[i].to_json().render(),
            want,
            "job {i}: event engine diverged from reference (serial driver)"
        );
        assert_eq!(
            parallel[i].to_json().render(),
            want,
            "job {i}: event engine diverged from reference (8-worker driver)"
        );
    }
}

#[test]
fn reference_reports_zero_ring_overflows_on_golden_matrix() {
    // The satellite overflow counters are part of the equivalence
    // surface (they render into the JSON); on every supported config
    // they must be zero on BOTH engines — a nonzero value would mean a
    // kernel outgrew the per-WG rings.
    let (_, oracle) = matrix();
    for (i, r) in oracle.iter().enumerate() {
        assert_eq!(r.debug.total(), 0, "job {i}: ring overflow on reference engine");
    }
}
