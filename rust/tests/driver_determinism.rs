//! Determinism contract of the simulation driver: parallel execution is
//! *bit-identical* to serial. The engine is deterministic per job; the
//! pool must not change results — only wall-clock — at any worker count,
//! with or without the memoizing cache.

use numa_attn::attn::AttnConfig;
use numa_attn::driver::{ReportCache, SimDriver, SimJob};
use numa_attn::mapping::ALL_POLICIES;
use numa_attn::sim::SimConfig;
use numa_attn::topology::{presets, Topology};
use numa_attn::workload::sweeps;

fn small_topo() -> Topology {
    Topology {
        name: "tiny".into(),
        num_xcds: 4,
        cus_per_xcd: 4,
        l2_bytes_per_xcd: 512 * 1024,
        ..presets::mi300x()
    }
}

/// A small sweep × all policies, forward, backward, and the two-phase
/// decode pass: 3 points × 4 policies × 3 passes = 36 jobs.
fn sweep_jobs() -> Vec<SimJob> {
    let topo = small_topo();
    let points = sweeps::mha_sensitivity(&[1024, 2048], &[1], &[4]);
    let extra = sweeps::backward_sweep(&[1024], &[1]);
    let mut jobs = Vec::new();
    for pt in points.iter().chain(&extra) {
        let cfg = AttnConfig { block_m: 128, block_n: 64, h_q: 4, h_k: 4, ..pt.cfg };
        for &p in &ALL_POLICIES {
            jobs.push(SimJob::forward(&topo, &cfg, SimConfig::forward(p)));
            jobs.push(SimJob::backward(&topo, &cfg, SimConfig::backward(p)));
            jobs.push(SimJob::decode(&topo, &cfg, SimConfig::decode(p, 2)));
        }
    }
    jobs
}

fn render_all(reports: &[numa_attn::SimReport]) -> Vec<String> {
    reports.iter().map(|r| r.to_json().render()).collect()
}

#[test]
fn threads_1_and_8_produce_byte_identical_reports() {
    let jobs = sweep_jobs();
    let serial = SimDriver::new(1).run_all(jobs.clone());
    let parallel = SimDriver::new(8).run_all(jobs.clone());
    assert_eq!(serial.len(), jobs.len());
    let a = render_all(&serial);
    let b = render_all(&parallel);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "job {i} diverged between 1 and 8 workers");
    }
}

#[test]
fn cache_off_matches_cache_on() {
    // Duplicate the job list so the cached driver serves half its batch
    // from memo hits — results must still be byte-identical with a
    // pass-through cache.
    let mut jobs = sweep_jobs();
    let dup = jobs.clone();
    jobs.extend(dup);
    let cached = SimDriver::new(4).run_all(jobs.clone());
    let uncached = SimDriver::with_cache(4, std::sync::Arc::new(ReportCache::disabled()))
        .run_all(jobs.clone());
    assert_eq!(render_all(&cached), render_all(&uncached));
}

#[test]
fn repeated_parallel_runs_are_stable() {
    let jobs = sweep_jobs();
    let d = SimDriver::new(8);
    let first = render_all(&d.run_all(jobs.clone()));
    let second = render_all(&d.run_all(jobs));
    assert_eq!(first, second);
}
