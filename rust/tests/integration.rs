//! Cross-module integration tests: simulator-over-workload shape checks,
//! experiment config files end-to-end, and the paper's headline orderings
//! at reduced scale (the full grids run in the benches).

use numa_attn::attn::AttnConfig;
use numa_attn::config::ExperimentConfig;
use numa_attn::coordinator::advise;
use numa_attn::driver::SimDriver;
use numa_attn::figures;
use numa_attn::mapping::Policy;
use numa_attn::sim::{simulate, simulate_backward, SimConfig};
use numa_attn::topology::presets;
use numa_attn::workload::{presets as models, sweeps};

fn sampled(p: Policy) -> SimConfig {
    SimConfig::sampled(p, &presets::mi300x(), 2)
}

#[test]
fn headline_ordering_holds_at_scale() {
    // SHF >= NHF >= block-first at the paper's stress point (reduced to
    // H=64/32K to keep the test fast).
    let topo = presets::mi300x();
    let cfg = AttnConfig::mha(2, 64, 32768, 128);
    let shf = simulate(&topo, &cfg, &sampled(Policy::SwizzledHeadFirst));
    let nhf = simulate(&topo, &cfg, &sampled(Policy::NaiveHeadFirst));
    let nbf = simulate(&topo, &cfg, &sampled(Policy::NaiveBlockFirst));
    assert!(shf.est_total_sec <= nhf.est_total_sec * 1.02);
    assert!(nhf.est_total_sec < nbf.est_total_sec);
    assert!(shf.l2_hit_pct() > 90.0, "SHF {:.1}%", shf.l2_hit_pct());
    assert!(nbf.l2_hit_pct() < 40.0, "NBF {:.1}%", nbf.l2_hit_pct());
}

#[test]
fn gqa_sbf_matches_shf_with_8_kv_heads() {
    // Paper Sec. 4.4 (Fig. 14): when KV groups == XCDs, Swizzled
    // Block-first co-locates and matches SHF; Naive Block-first doesn't.
    let topo = presets::mi300x();
    let cfg = models::llama3_70b().attn(2, 32768);
    let shf = simulate(&topo, &cfg, &sampled(Policy::SwizzledHeadFirst));
    let sbf = simulate(&topo, &cfg, &sampled(Policy::SwizzledBlockFirst));
    let nbf = simulate(&topo, &cfg, &sampled(Policy::NaiveBlockFirst));
    let rel_sbf = shf.est_total_sec / sbf.est_total_sec;
    assert!(rel_sbf > 0.95, "SBF rel {rel_sbf:.3}");
    assert!(shf.est_total_sec / nbf.est_total_sec < 0.95);
}

#[test]
fn backward_speedup_is_modest() {
    // Paper Fig. 16: backward gains bounded (~1.10x at 128K).
    let topo = presets::mi300x();
    let cfg = AttnConfig::mha(1, 128, 16384, 128);
    let shf = simulate_backward(&topo, &cfg, &SimConfig {
        ..SimConfig::backward(Policy::SwizzledHeadFirst)
    });
    let nbf = simulate_backward(&topo, &cfg, &SimConfig {
        ..SimConfig::backward(Policy::NaiveBlockFirst)
    });
    let speedup = nbf.est_total_sec / shf.est_total_sec;
    assert!((0.95..1.45).contains(&speedup), "speedup {speedup:.3}");
}

#[test]
fn unified_gpu_shows_no_numa_effect() {
    // Fig. 1a control: one die, one L2 -> mapping barely matters.
    let mut topo = presets::unified_single_die();
    topo.cus_per_xcd = 64; // keep runtime bounded
    let cfg = AttnConfig::mha(1, 32, 8192, 128);
    let shf = simulate(&topo, &cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2));
    let nbf = simulate(&topo, &cfg, &SimConfig::sampled(Policy::NaiveBlockFirst, &topo, 2));
    let ratio = nbf.est_total_sec / shf.est_total_sec;
    assert!((0.9..1.12).contains(&ratio), "ratio {ratio:.3}");
}

#[test]
fn chunk_mismatch_degrades_swizzle() {
    // Paper Sec. 2.2: the driver's chunk size can change across GPU
    // generations; a chunk-1 swizzle on chunk!=1 hardware loses locality.
    let cfg = AttnConfig::mha(1, 64, 16384, 128);
    let mut chunk1 = presets::mi300x();
    chunk1.dispatch_chunk = 1;
    let mut chunk4 = presets::mi300x();
    chunk4.dispatch_chunk = 4;
    let good = simulate(&chunk1, &cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &chunk1, 2));
    let bad = simulate(&chunk4, &cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &chunk4, 2));
    assert!(
        bad.l2_hit_pct() < good.l2_hit_pct() - 5.0,
        "chunk-4 {:.1}% vs chunk-1 {:.1}%",
        bad.l2_hit_pct(),
        good.l2_hit_pct()
    );
}

#[test]
fn experiment_config_roundtrip() {
    let text = r#"
topology = "quad_die"

[attention]
batch = 1
h_q = 16
h_k = 4
n_ctx = 4096
d_head = 64
causal = true

[sim]
policy = "nbf"
generations = 1
seed = 9
prefetch_depth = 2
"#;
    let exp = ExperimentConfig::parse(text).unwrap();
    let topo = exp.topology().unwrap();
    assert_eq!(topo.num_xcds, 4);
    let attn = exp.attn().unwrap();
    assert!(attn.causal);
    let pols = exp.policies().unwrap();
    assert_eq!(pols, vec![Policy::NaiveBlockFirst]);
    let sc = exp.sim(pols[0]).unwrap();
    assert_eq!(sc.prefetch_depth, 2);
    let r = simulate(&topo, &attn, &sc);
    assert!(r.est_total_sec > 0.0);
    assert!(!r.truncated);
}

#[test]
fn decode_experiment_config_roundtrip() {
    // The serving-regime decode workload end to end: INI file -> decode
    // sim config -> two-phase simulation, on a scaled-down topology.
    let text = r#"
topology = "quad_die"

[attention]
batch = 1
h_q = 16
h_k = 4
n_ctx = 8192
d_head = 64

[sim]
policy = "shf"
kernel = "decode"
num_splits = 4
"#;
    let exp = ExperimentConfig::parse(text).unwrap();
    assert_eq!(exp.kernel().unwrap(), numa_attn::config::ExpKernel::Decode(4));
    let topo = exp.topology().unwrap();
    let attn = exp.attn().unwrap();
    let sc = exp.sim(Policy::SwizzledHeadFirst).unwrap();
    let r = numa_attn::sim::simulate_decode(&topo, &attn, &sc);
    // Phase 1: batch*h_q*splits WGs; phase 2: batch*h_q WGs.
    assert_eq!(r.simulated_wgs, 16 * 4 + 16);
    assert!(!r.truncated);
    assert!(r.est_total_sec > 0.0);
    // Decode streams the whole KV once in phase 1 at minimum.
    assert!(r.hbm.bytes_read >= attn.kv_bytes_per_head() * attn.h_k as u64);
}

#[test]
fn decode_advisor_fills_device_and_ranks() {
    // The decode advisor picks a split count that fills the device and
    // its recommendation is the best-ranked projection.
    let topo = presets::mi300x();
    let cfg = models::llama3_70b().attn(1, 16384);
    let advice = numa_attn::coordinator::advise_decode(&topo, &cfg, None);
    let splits = advice.num_splits.unwrap();
    assert!(cfg.batch * cfg.h_q * splits >= topo.total_wg_slots());
    assert!(splits <= cfg.num_col_blocks());
    let best_rel = advice
        .projections
        .iter()
        .map(|(_, _, rel)| *rel)
        .fold(0.0f64, f64::max);
    assert!(best_rel <= 1.0 + 1e-9);
    assert!(advice.projections.iter().any(|(p, _, _)| *p == advice.recommended));
}

#[test]
fn advisor_consistent_with_figures() {
    // The advisor's recommendation must be the best policy in the
    // corresponding figure row.
    let topo = presets::mi300x();
    let cfg = AttnConfig::mha(1, 64, 32768, 128);
    let advice = advise(&topo, &cfg);
    assert_eq!(advice.recommended, Policy::SwizzledHeadFirst);
    let best_rel = advice
        .projections
        .iter()
        .map(|(_, _, rel)| *rel)
        .fold(0.0f64, f64::max);
    assert!(best_rel <= 1.0 + 1e-9);
}

#[test]
fn quick_fig13_extremes() {
    // One end-to-end figure run (quick sweep) sanity-checking both ends.
    let topo = presets::mi300x();
    let fig = figures::fig13(&SimDriver::new(4), &topo, true);
    let shf_small = fig.value("H=8 N=2K B=1", Policy::SwizzledHeadFirst).unwrap();
    let shf_big = fig.value("H=128 N=128K B=8", Policy::SwizzledHeadFirst).unwrap();
    let nbf_big = fig.value("H=128 N=128K B=8", Policy::NaiveBlockFirst).unwrap();
    assert!(shf_small > 80.0);
    assert!(shf_big > 80.0);
    assert!(nbf_big < 20.0);
}

#[test]
fn sweep_labels_are_unique() {
    let pts = sweeps::mha_sensitivity(&sweeps::TABLE2_N_CTX, &sweeps::TABLE2_BATCH, &sweeps::TABLE2_HEADS);
    let mut labels: Vec<&str> = pts.iter().map(|p| p.label.as_str()).collect();
    labels.sort_unstable();
    let before = labels.len();
    labels.dedup();
    assert_eq!(labels.len(), before);
}
