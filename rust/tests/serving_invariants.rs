//! Serving invariant property suite (the pin this PR's refactors — and
//! every later one — must keep green). The continuous-batching loop now
//! has two step compositions (monolithic prefill and chunked
//! prefill+decode mixed steps, docs/SERVING.md §6); these properties
//! hold across BOTH, for every seed, chunk size, and step budget in the
//! grid:
//!
//! * **Token conservation** — every admitted session's prompt tokens are
//!   prefilled exactly once (monolithically or as contiguous chunks) and
//!   its decode budget is emitted exactly once;
//! * **Capacity** — the active set never exceeds `max_active`;
//! * **Session conservation** — completed + active + backlog always sums
//!   to the trace size;
//! * **Budget** — a mixed step is composed under `step_token_budget`:
//!   the decode-phase count at composition time plus the planned chunk
//!   tokens never exceed it. (A session whose prefill completes via
//!   this step's chunk emits its first token the same step — the
//!   deliberate monolithic-admission carve-out the golden-equivalence
//!   pins rely on — so *emitted* tokens may exceed the budget by at
//!   most the number of prefills completing that step.);
//! * **Ordering** — a session's first token precedes (or shares the step
//!   of) its retirement, and TTFT can never exceed the run's span.
//!
//! The disaggregated prefill/decode loop (docs/DISAGG.md) adds its own
//! conservation laws, swept across SLO mix × pool split × chunk size ×
//! seed in `prop_disagg_conserves_sessions_and_handoff_bytes`: every
//! session's KV bytes cross the interconnect exactly once (transferred
//! or credited, never both); completed + active + transit + backlog
//! covers the trace across BOTH pools at every step; a preempted batch
//! chunk is re-planned exactly once from its frozen cursor; and no
//! session decodes before its handoff has landed.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use numa_attn::coordinator::{
    serve_decode_disagg_traced, serve_decode_with, DisaggConfig, PrefillChunk, ServeConfig,
    StepBatcher,
};
use numa_attn::driver::SimDriver;
use numa_attn::mapping::Policy;
use numa_attn::mem::{block_bytes, prompt_keys, KvPool};
use numa_attn::topology::{presets, Topology};
use numa_attn::workload::{Session, SessionGenerator};

/// Scaled-down MI300X (same shape as tests/serving_loop.rs) so the
/// priced properties run in test time.
fn fast_topo() -> Topology {
    Topology {
        cus_per_xcd: 8,
        l2_bytes_per_xcd: 1024 * 1024,
        hbm_bytes_per_sec: 1.1e12,
        ..presets::mi300x()
    }
}

/// The (chunk_tokens, step_token_budget) grid every property sweeps:
/// off, small chunks uncapped, small chunks tightly budgeted, mid-size
/// chunks budgeted, and a chunk wider than any prompt (the degenerate
/// one-chunk regime).
const CHUNK_GRID: [(usize, usize); 5] =
    [(0, 0), (256, 0), (256, 512), (512, 1024), (1 << 20, 0)];

fn tiny_serve(seed: u64, chunk_tokens: usize, step_token_budget: usize) -> ServeConfig {
    ServeConfig {
        h_q: 16,
        h_k: 8,
        d_head: 64,
        kv_cap: 8192,
        kv_bucket: 2048,
        arrival_per_sec: 1500.0,
        prefill_lengths: vec![640, 1024, 2048],
        decode_tokens: vec![4, 12],
        sessions: 7,
        max_active: 3,
        max_steps: 400,
        chunk_tokens,
        step_token_budget,
        seed,
        ..ServeConfig::default()
    }
}

fn trace_of(cfg: &ServeConfig) -> Vec<Session> {
    SessionGenerator::new(
        cfg.seed,
        cfg.arrival_per_sec,
        cfg.prefill_lengths.clone(),
        cfg.decode_tokens.clone(),
    )
    .take(cfg.sessions)
}

#[test]
fn prop_batcher_conserves_every_token_across_the_chunk_grid() {
    for seed in [1u64, 7, 23] {
        for (chunk, budget) in CHUNK_GRID {
            let cfg = tiny_serve(seed, chunk, budget);
            cfg.validate().unwrap();
            let trace = trace_of(&cfg);
            let total = trace.len();
            let by_id: HashMap<u64, Session> =
                trace.iter().map(|s| (s.id, s.clone())).collect();

            let mut b = StepBatcher::new(trace.clone(), cfg.max_active, chunk);
            // Per-session accounting rebuilt from the batcher's outputs.
            let mut prefilled_monolithic: HashMap<u64, usize> = HashMap::new();
            let mut chunk_cursor: HashMap<u64, usize> = HashMap::new();
            let mut emitted: HashMap<u64, usize> = HashMap::new();
            let mut first_emit_step: HashMap<u64, usize> = HashMap::new();
            let mut retire_step: HashMap<u64, usize> = HashMap::new();

            let mut now = 0.0f64;
            let mut step = 0usize;
            while !b.done() {
                assert!(step < 10_000, "seed {seed} chunk {chunk}: loop must terminate");
                if b.active().is_empty() {
                    match b.next_arrival_sec() {
                        Some(t) => now = now.max(t),
                        None => break,
                    }
                }
                let newly = b.admit(now);
                assert!(
                    b.active().len() <= cfg.max_active,
                    "max_active exceeded: {} > {}",
                    b.active().len(),
                    cfg.max_active
                );
                assert_eq!(
                    b.completed() + b.active().len() + b.backlog_len(),
                    total,
                    "completed + active + backlog must always cover the trace"
                );

                if chunk == 0 {
                    // Monolithic: admission IS the (single) prefill.
                    for s in &newly {
                        assert!(
                            prefilled_monolithic.insert(s.id, s.prefill).is_none(),
                            "session {} prefilled twice",
                            s.id
                        );
                    }
                } else {
                    let decoding = b.decoding();
                    let plan_budget = if budget == 0 {
                        usize::MAX
                    } else {
                        budget.saturating_sub(decoding)
                    };
                    let planned = b.plan_chunks(plan_budget);
                    let chunk_tokens: usize = planned.iter().map(PrefillChunk::tokens).sum();
                    if budget > 0 {
                        assert!(
                            decoding + chunk_tokens <= budget,
                            "step spent {} tokens over budget {budget}",
                            decoding + chunk_tokens
                        );
                    }
                    for c in &planned {
                        assert!(c.tokens() >= 1 && c.tokens() <= chunk);
                        let cur = chunk_cursor.entry(c.id).or_insert(0);
                        assert_eq!(
                            *cur, c.start,
                            "session {}: chunks must stream contiguously (each prompt \
                             token exactly once)",
                            c.id
                        );
                        *cur = c.end;
                        assert!(c.end <= by_id[&c.id].prefill, "chunk past the prompt");
                    }
                }

                let will_emit: Vec<u64> = b
                    .active()
                    .iter()
                    .filter(|a| a.prefill_complete())
                    .map(|a| a.session.id)
                    .collect();
                assert_eq!(b.advance_step(), will_emit.len());
                for id in will_emit {
                    let e = emitted.entry(id).or_insert(0);
                    *e += 1;
                    first_emit_step.entry(id).or_insert(step);
                    if *e == by_id[&id].decode_tokens {
                        retire_step.insert(id, step);
                    }
                }
                now += 1e-3;
                step += 1;
            }

            assert!(b.done());
            assert_eq!(b.completed(), total, "every session retires");
            for s in &trace {
                assert_eq!(
                    emitted[&s.id], s.decode_tokens,
                    "session {}: decode budget emitted exactly once",
                    s.id
                );
                if chunk == 0 {
                    assert_eq!(prefilled_monolithic[&s.id], s.prefill);
                } else {
                    assert_eq!(
                        chunk_cursor[&s.id], s.prefill,
                        "session {}: chunked prompt tokens must sum to the prompt",
                        s.id
                    );
                }
                assert!(
                    first_emit_step[&s.id] <= retire_step[&s.id],
                    "first token after retirement?!"
                );
            }
        }
    }
}

#[test]
fn prop_serve_stats_conserve_and_order_across_the_chunk_grid() {
    let driver = SimDriver::new(2);
    let topo = fast_topo();
    for seed in [3u64, 9] {
        for (chunk, budget) in CHUNK_GRID {
            let cfg = tiny_serve(seed, chunk, budget);
            let s = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
            let label = format!("seed {seed} chunk {chunk} budget {budget}");
            assert!(!s.truncated, "{label}: trace must drain");
            assert_eq!(s.sessions_completed, cfg.sessions, "{label}");

            let trace = trace_of(&cfg);
            let want_decode: u64 = trace.iter().map(|t| t.decode_tokens as u64).sum();
            let want_prefill: u64 = trace.iter().map(|t| t.prefill as u64).sum();
            assert_eq!(s.tokens, want_decode, "{label}: decode-token conservation");
            assert_eq!(s.prefill_tokens, want_prefill, "{label}: prompt-token conservation");

            assert!(s.ttft_p50_ms > 0.0, "{label}");
            assert!(s.ttft_p50_ms <= s.ttft_p99_ms, "{label}: TTFT percentile order");
            assert!(
                s.ttft_p99_ms <= s.sim_sec * 1e3,
                "{label}: a session's TTFT cannot exceed the run ({} > {})",
                s.ttft_p99_ms,
                s.sim_sec * 1e3
            );
            assert!(s.tpot_p50_ms > 0.0 && s.tpot_p50_ms <= s.tpot_p99_ms, "{label}");
            assert!(s.prefill_sec > 0.0 && s.prefill_sec < s.sim_sec, "{label}");
            assert!(s.tokens_per_sec > 0.0, "{label}");
            assert_eq!(s.advisor_consults, s.distinct_geometries, "{label}");
        }
    }
}

/// The trace the serving loop actually runs when sharing is on: the
/// shared-prefix draw rides its own RNG stream on top of the base
/// arrival/prompt/decode trace (pinned by workload tests).
fn shared_trace(cfg: &ServeConfig) -> Vec<Session> {
    SessionGenerator::new(
        cfg.seed,
        cfg.arrival_per_sec,
        cfg.prefill_lengths.clone(),
        cfg.decode_tokens.clone(),
    )
    .with_prefix_sharing(cfg.prefix_share_pct, cfg.shared_span())
    .take(cfg.sessions)
}

/// The paged-pool sharing grid: (prefix_share_pct, kv_block_tokens,
/// kv_capacity_mb, chunk_tokens). Covers partial-tail blocks (300 does
/// not divide the 640-token minimum prompt), an eviction-heavy 1 MiB
/// budget (4 blocks at 128 tokens, 1 block at 300), unlimited budgets,
/// and both step compositions.
const SHARE_GRID: [(f64, usize, usize, usize); 8] = [
    (50.0, 128, 0, 0),
    (100.0, 128, 0, 0),
    (50.0, 128, 1, 0),
    (100.0, 300, 1, 0),
    (50.0, 128, 0, 256),
    (100.0, 128, 1, 256),
    (100.0, 300, 0, 256),
    (50.0, 300, 1, 256),
];

#[test]
fn prop_pool_conserves_prompt_tokens_across_the_sharing_grid() {
    // The paged-pool conservation law (docs/KVCACHE.md): every admitted
    // prompt token is either CHARGED to exactly one prefill launch
    // (`prefill_tokens`) or SATISFIED by exactly one resident shared
    // block (`kv_shared_tokens`) — their sum is the trace's prompt
    // total, for every share ratio, block size, budget, and step
    // composition. A shared prefix that was evicted under budget
    // pressure re-enters on the charged side of the ledger (it is
    // re-prefilled by the session that readmits it) — the sum never
    // double-counts and never drops a token either way.
    let driver = SimDriver::new(2);
    let topo = fast_topo();
    for seed in [3u64, 9] {
        for (share, block, cap_mb, chunk) in SHARE_GRID {
            let cfg = ServeConfig {
                kv_block_tokens: block,
                prefix_share_pct: share,
                kv_capacity_mb: cap_mb,
                ..tiny_serve(seed, chunk, 0)
            };
            cfg.validate().unwrap();
            let s = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
            let label =
                format!("seed {seed} share {share} block {block} cap {cap_mb} chunk {chunk}");
            assert!(!s.truncated, "{label}: trace must drain");
            assert_eq!(s.sessions_completed, cfg.sessions, "{label}");

            let trace = trace_of(&cfg);
            let want_decode: u64 = trace.iter().map(|t| t.decode_tokens as u64).sum();
            let want_prefill: u64 = trace.iter().map(|t| t.prefill as u64).sum();
            assert_eq!(s.tokens, want_decode, "{label}: decode-token conservation");
            assert_eq!(
                s.prefill_tokens + s.kv_shared_tokens,
                want_prefill,
                "{label}: charged + credited must cover every prompt token exactly once"
            );
            if cap_mb == 0 && share == 100.0 {
                assert!(s.kv_shared_tokens > 0, "{label}: unlimited 100%-share must credit");
            }
            assert!(
                (0.0..=100.0).contains(&s.kv_xcd_affinity_pct),
                "{label}: affinity is a percentage ({})",
                s.kv_xcd_affinity_pct
            );
        }
    }
}

#[test]
fn prop_pool_budget_and_lease_accounting_hold_step_by_step() {
    // Replay the serving loop's admission/retirement protocol against a
    // pool directly (the priced executor is irrelevant to these
    // invariants) and check, after EVERY step: the pool never exceeds
    // its byte budget even transiently (peak ≤ budget), refcount
    // conservation (sum of refcounts == sum of live lease lengths), and
    // the chunk stream of each credited session starts exactly at its
    // credited offset — so charged + credited == the prompt, token for
    // token, even when eviction forces a later sharer to re-prefill.
    for seed in [1u64, 7, 23] {
        for (share, block) in [(0.0f64, 128usize), (50.0, 128), (100.0, 128), (100.0, 300)] {
            let cfg = ServeConfig {
                kv_block_tokens: block,
                prefix_share_pct: share,
                kv_capacity_mb: 1,
                ..tiny_serve(seed, 256, 0)
            };
            let label = format!("seed {seed} share {share} block {block}");
            let trace = shared_trace(&cfg);
            let by_id: HashMap<u64, Session> = trace.iter().map(|s| (s.id, s.clone())).collect();
            let mut b = StepBatcher::new(trace.clone(), cfg.max_active, cfg.chunk_tokens);
            let bb = block_bytes(block, cfg.h_k, cfg.d_head, cfg.dtype_bytes);
            let budget_bytes = cfg.kv_capacity_mb as u64 * 1024 * 1024;
            let mut pool = KvPool::new(bb, budget_bytes);
            let mut credited: HashMap<u64, usize> = HashMap::new();
            let mut charged: HashMap<u64, usize> = HashMap::new();
            let mut cursor: HashMap<u64, usize> = HashMap::new();

            let mut now = 0.0f64;
            let mut step = 0usize;
            while !b.done() {
                assert!(step < 10_000, "{label}: loop must terminate");
                if b.active().is_empty() {
                    match b.next_arrival_sec() {
                        Some(t) => now = now.max(t),
                        None => break,
                    }
                }
                for s in b.admit(now) {
                    let keys = prompt_keys(s.id, s.prefill, s.shared_prefix, block);
                    let got = pool.acquire(s.id, &keys);
                    let t = (got.credited_blocks * block).min(s.prefill);
                    credited.insert(s.id, t);
                    cursor.insert(s.id, t);
                    if t > 0 {
                        b.credit_prefix(s.id, t);
                    }
                }
                for c in b.plan_chunks(usize::MAX) {
                    assert_eq!(
                        cursor[&c.id], c.start,
                        "{label}: session {} must stream from its credited offset",
                        c.id
                    );
                    cursor.insert(c.id, c.end);
                    *charged.entry(c.id).or_insert(0) += c.tokens();
                    assert!(c.end <= by_id[&c.id].prefill, "{label}: chunk past the prompt");
                }
                b.advance_step();
                for id in b.drain_retired() {
                    pool.release(id);
                }
                assert!(
                    pool.peak_used_bytes() <= budget_bytes,
                    "{label}: pool peak {} exceeded budget {budget_bytes}",
                    pool.peak_used_bytes()
                );
                assert_eq!(pool.total_refs(), pool.leased_blocks(), "{label}: ref conservation");
                now += 1e-3;
                step += 1;
            }

            assert_eq!(b.completed(), trace.len(), "{label}: every session retires");
            assert_eq!(pool.total_refs(), 0, "{label}: every lease released at retirement");
            for s in &trace {
                assert_eq!(
                    credited.get(&s.id).copied().unwrap_or(0)
                        + charged.get(&s.id).copied().unwrap_or(0),
                    s.prefill,
                    "{label}: session {} prompt tokens charged-or-credited exactly once",
                    s.id
                );
            }
            // The all-private cell exercises eviction deterministically:
            // 7 disjoint chains churn through a 4-block budget, and the
            // 4th admission always lands after a retirement has dropped
            // an earlier chain to refcount 0 (max_active is 3).
            if share == 0.0 && block == 128 {
                assert!(pool.evictions() > 0, "{label}: grid never hit the eviction path");
            }
        }
    }
}

#[test]
fn prop_chunking_never_changes_what_is_served() {
    // The scheduling knob changes WHEN work runs, never WHAT runs: every
    // grid point serves the identical token totals, and the degenerate
    // one-chunk regime reproduces the monolithic stats byte-for-byte
    // (the full JSON golden pins live in tests/serving_loop.rs).
    let driver = SimDriver::new(2);
    let topo = fast_topo();
    let off = serve_decode_with(&driver, &topo, &tiny_serve(5, 0, 0), Policy::NaiveHeadFirst);
    for (chunk, budget) in &CHUNK_GRID[1..] {
        let cfg = tiny_serve(5, *chunk, *budget);
        let s = serve_decode_with(&driver, &topo, &cfg, Policy::NaiveHeadFirst);
        assert_eq!(s.tokens, off.tokens);
        assert_eq!(s.prefill_tokens, off.prefill_tokens);
        assert_eq!(s.sessions_completed, off.sessions_completed);
    }
}

/// One cell of the disaggregated grid on the tiny GQA-8 geometry: pool
/// sizes must divide `h_k = 8`, both step compositions, the SLO mix
/// from all-batch to all-interactive, and a 100%-shared cell whose
/// decode-pool prefix hits turn handoff bytes into credits. The
/// chunked mixed cells set a deliberately unreachable 0.01 ms TTFT
/// objective so the batch-preemption path fires inside the grid.
fn tiny_disagg(
    seed: u64,
    (prefill_devices, decode_devices): (usize, usize),
    (chunk, budget): (usize, usize),
    interactive_pct: f64,
    share: f64,
) -> DisaggConfig {
    let serve = ServeConfig {
        kv_block_tokens: if share > 0.0 { 256 } else { 0 },
        prefix_share_pct: share,
        kv_capacity_mb: if share > 0.0 { 64 } else { 0 },
        ..tiny_serve(seed, chunk, budget)
    };
    DisaggConfig {
        serve,
        prefill_devices,
        decode_devices,
        interactive_pct,
        ttft_slo_ms: if chunk > 0 && interactive_pct > 0.0 { 0.01 } else { 0.0 },
        ..DisaggConfig::default()
    }
}

#[test]
fn prop_disagg_conserves_sessions_and_handoff_bytes() {
    let driver = SimDriver::new(2);
    let topo = fast_topo();
    let mut grid_preemptions = 0u64;
    for seed in [13u64, 99] {
        for pools in [(1usize, 1usize), (2, 2), (1, 2)] {
            for comp in [(0usize, 0usize), (256, 512)] {
                for pct in [0.0f64, 50.0, 100.0] {
                    for share in [0.0f64, 100.0] {
                        let cfg = tiny_disagg(seed, pools, comp, pct, share);
                        let label = format!(
                            "seed {seed} pools {pools:?} comp {comp:?} pct {pct} share {share}"
                        );
                        let (stats, trace) = serve_decode_disagg_traced(
                            &driver,
                            &topo,
                            &cfg,
                            Policy::SwizzledHeadFirst,
                        );
                        let total = trace.sessions.len();
                        assert_eq!(total, cfg.serve.sessions, "{label}");
                        assert!(!stats.serve.truncated, "{label}: trace must drain");
                        assert_eq!(stats.serve.sessions_completed, total, "{label}");
                        let extras = stats.extras.as_ref().expect("disagg run has extras");
                        grid_preemptions += extras.preemptions;

                        // KV handoff: every session's bytes cross the
                        // link exactly once — transferred or credited
                        // against resident shared blocks, never both.
                        assert_eq!(extras.handoffs as usize, total, "{label}");
                        assert_eq!(trace.handoffs.len(), total, "{label}");
                        let by_id: HashMap<u64, &Session> =
                            trace.sessions.iter().map(|s| (s.id, s)).collect();
                        let mut handed_off = BTreeSet::new();
                        for h in &trace.handoffs {
                            assert!(
                                handed_off.insert(h.id),
                                "{label}: session {} handed off twice",
                                h.id
                            );
                            let s = by_id[&h.id];
                            assert_eq!(h.slo, s.slo, "{label}");
                            assert_eq!(
                                h.total_bytes,
                                cfg.session_kv_bytes(s.prefill),
                                "{label}: session {} handoff must price the whole KV cache",
                                h.id
                            );
                            assert_eq!(
                                h.transferred_bytes + h.credited_bytes,
                                h.total_bytes,
                                "{label}: session {} transferred-or-credited exactly once",
                                h.id
                            );
                            if share == 0.0 {
                                assert_eq!(h.credited_bytes, 0, "{label}: no pool, no credit");
                            }
                            assert!(h.ready_sec >= h.sent_sec, "{label}: link time is causal");
                            let admitted = h.admitted_sec.unwrap_or_else(|| {
                                panic!("{label}: session {} never reached decode", h.id)
                            });
                            assert!(
                                admitted >= h.ready_sec - 1e-9,
                                "{label}: session {} decoded before its handoff landed \
                                 ({admitted} < {})",
                                h.id,
                                h.ready_sec
                            );
                        }
                        assert_eq!(
                            extras.handoff_total_bytes,
                            trace.handoffs.iter().map(|h| h.total_bytes).sum::<u64>(),
                            "{label}"
                        );
                        assert_eq!(
                            extras.handoff_transferred_bytes + extras.handoff_credited_bytes,
                            extras.handoff_total_bytes,
                            "{label}: byte totals transferred-or-credited, never both"
                        );
                        if share > 0.0 {
                            assert!(
                                extras.handoff_credited_bytes > 0,
                                "{label}: 100%-shared prefixes must credit handoff bytes"
                            );
                        }

                        // Cross-pool session conservation at EVERY step:
                        // backlog + prefill-active + in-transit +
                        // decode-active + completed covers the trace.
                        for (i, a) in trace.audits.iter().enumerate() {
                            assert_eq!(
                                a.backlog
                                    + a.prefill_active
                                    + a.transit
                                    + a.decode_active
                                    + a.completed,
                                total,
                                "{label}: step audit {i} ({:?} pool) leaks a session",
                                a.pool
                            );
                        }
                        assert_eq!(trace.audits.last().unwrap().completed, total, "{label}");
                        assert_eq!(
                            extras.prefill_steps + extras.decode_steps,
                            trace.audits.len(),
                            "{label}: one audit per step"
                        );

                        // Per-class decode tokens partition the run's.
                        assert_eq!(
                            extras.interactive.tokens + extras.batch.tokens,
                            stats.serve.tokens,
                            "{label}"
                        );
                        let want: u64 =
                            trace.sessions.iter().map(|s| s.decode_tokens as u64).sum();
                        assert_eq!(stats.serve.tokens, want, "{label}");
                        assert_eq!(
                            extras.interactive.sessions + extras.batch.sessions,
                            total,
                            "{label}: every session belongs to exactly one class"
                        );
                        if pct == 0.0 {
                            assert_eq!(extras.interactive.sessions, 0, "{label}");
                        }
                        if pct == 100.0 {
                            assert_eq!(extras.batch.sessions, 0, "{label}");
                        }

                        // Every prompt token prefills exactly once: the
                        // chunk stream is gapless from the credited
                        // offset to the end of the prompt.
                        let credited: HashMap<u64, usize> =
                            trace.credited_prefill.iter().copied().collect();
                        let mut chunks_of: BTreeMap<u64, Vec<(usize, usize)>> = BTreeMap::new();
                        for c in &trace.chunks {
                            chunks_of.entry(c.id).or_default().push((c.start, c.end));
                        }
                        for s in &trace.sessions {
                            let start = credited.get(&s.id).copied().unwrap_or(0).min(s.prefill);
                            let mut cursor = start;
                            let empty = Vec::new();
                            for &(st, en) in chunks_of.get(&s.id).unwrap_or(&empty) {
                                assert_eq!(
                                    st, cursor,
                                    "{label}: session {} chunk gap or overlap",
                                    s.id
                                );
                                assert!(en > st && en <= s.prefill, "{label}: chunk bounds");
                                cursor = en;
                            }
                            assert_eq!(
                                cursor, s.prefill,
                                "{label}: session {} prompt not covered exactly once",
                                s.id
                            );
                        }

                        // A preempted batch chunk freezes its cursor and
                        // is re-planned exactly once from that offset
                        // (dedup to distinct (id, cursor): a chunk kept
                        // waiting stays in consecutive at-risk records).
                        let frozen: BTreeSet<(u64, usize)> =
                            trace.preemptions.iter().map(|p| (p.id, p.cursor)).collect();
                        for &(id, cursor) in &frozen {
                            let hits = trace
                                .chunks
                                .iter()
                                .filter(|c| c.id == id && c.start == cursor)
                                .count();
                            assert_eq!(
                                hits, 1,
                                "{label}: preempted (session {id}, cursor {cursor}) must be \
                                 re-planned exactly once, got {hits}"
                            );
                        }
                        if !trace.preemptions.is_empty() {
                            assert!(extras.preemptions > 0, "{label}");
                        }
                    }
                }
            }
        }
    }
    assert!(
        grid_preemptions > 0,
        "the tight-TTFT chunked cells never exercised the preemption path"
    );
}
