//! Contracts of the tensor-parallel cluster serving path
//! (docs/CLUSTER.md):
//!
//! * a `tp = 1` cluster is not "approximately" the single-device path —
//!   its serving stats are **byte-identical** to `serve_decode_with` on
//!   the same device, at any driver worker count (the acceptance pin of
//!   the executor refactor: the cluster generalization cost the
//!   historical path nothing);
//! * the paper's level-2 mapping win survives head sharding:
//!   SwizzledHeadFirst's tokens/s AND decode L2 hit rate are at least
//!   NaiveHeadFirst's at every TP degree tested;
//! * sharding shrinks per-device work: the prefill kernel time a TP-2
//!   deployment charges is below TP-1's on the same trace, interconnect
//!   all-gather included.

use numa_attn::cluster::{ClusterTopology, ShardPlan, ShardStrategy};
use numa_attn::coordinator::{
    serve_decode_cluster_with, serve_decode_disagg_with, serve_decode_faulty_with,
    serve_decode_with, DisaggConfig, FaultPlan, ServeConfig,
};
use numa_attn::driver::SimDriver;
use numa_attn::mapping::Policy;
use numa_attn::topology::{presets, Topology};

/// Scaled-down MI300X (same shape as tests/serving_loop.rs) so the loop
/// runs in test time.
fn fast_topo() -> Topology {
    Topology {
        cus_per_xcd: 8,
        l2_bytes_per_xcd: 1024 * 1024,
        hbm_bytes_per_sec: 1.1e12,
        ..presets::mi300x()
    }
}

fn small_serve() -> ServeConfig {
    ServeConfig {
        h_q: 16,
        h_k: 8,
        d_head: 64,
        kv_cap: 16384,
        kv_bucket: 2048,
        arrival_per_sec: 1000.0,
        prefill_lengths: vec![2040, 4096],
        decode_tokens: vec![8, 24],
        sessions: 8,
        max_active: 4,
        max_steps: 300,
        seed: 13,
        ..ServeConfig::default()
    }
}

fn tp_cluster(device: &Topology, cfg: &ServeConfig, tp: usize) -> (ClusterTopology, ShardPlan) {
    let cluster = ClusterTopology::node_of(device, tp);
    let plan = ShardPlan::new(&cfg.base_geometry(), tp, ShardStrategy::Contiguous).unwrap();
    (cluster, plan)
}

#[test]
fn tp1_cluster_serve_is_byte_identical_to_single_device() {
    // The acceptance pin: for every policy, at 1 AND 8 driver workers,
    // the tp=1 cluster path and the historical single-device path render
    // the same JSON byte-for-byte. A one-device cluster launches the
    // identical jobs (shard-local geometry == global geometry) and its
    // ring all-gather charge is exactly 0.0.
    let topo = fast_topo();
    let cfg = small_serve();
    let (cluster, plan) = tp_cluster(&topo, &cfg, 1);
    for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
        for threads in [1usize, 8] {
            let single = serve_decode_with(&SimDriver::new(threads), &topo, &cfg, policy);
            let clustered = serve_decode_cluster_with(
                &SimDriver::new(threads),
                &cluster,
                &plan,
                &cfg,
                policy,
            );
            assert_eq!(
                single.to_json().render(),
                clustered.to_json().render(),
                "{policy} @ {threads} workers: tp=1 cluster diverged from single-device"
            );
        }
    }
}

#[test]
fn cluster_serve_is_byte_identical_across_worker_counts() {
    // The determinism contract extends to real sharding: a tp=2 run is
    // byte-identical at 1 and 8 driver workers.
    let topo = fast_topo();
    let cfg = small_serve();
    let (cluster, plan) = tp_cluster(&topo, &cfg, 2);
    let serial = serve_decode_cluster_with(
        &SimDriver::new(1),
        &cluster,
        &plan,
        &cfg,
        Policy::SwizzledHeadFirst,
    );
    let parallel = serve_decode_cluster_with(
        &SimDriver::new(8),
        &cluster,
        &plan,
        &cfg,
        Policy::SwizzledHeadFirst,
    );
    assert_eq!(
        serial.to_json().render(),
        parallel.to_json().render(),
        "tp=2 cluster serve diverged between 1 and 8 workers"
    );
}

#[test]
fn shf_at_least_nhf_at_every_tp_degree() {
    // The two-level claim, end to end: head sharding must not lose the
    // paper's mapping win. At each TP degree whose shard-local head
    // count keeps the swizzled policies applicable (16 heads / 8 XCDs
    // limits this config to tp <= 2), SHF serves tokens at least as fast
    // as NHF and sees at least its decode L2 hit rate, under the
    // identical arrival trace.
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let cfg = small_serve();
    for tp in [1usize, 2] {
        let (cluster, plan) = tp_cluster(&topo, &cfg, tp);
        let shf =
            serve_decode_cluster_with(&driver, &cluster, &plan, &cfg, Policy::SwizzledHeadFirst);
        let nhf = serve_decode_cluster_with(&driver, &cluster, &plan, &cfg, Policy::NaiveHeadFirst);
        assert_eq!(shf.tokens, nhf.tokens, "tp={tp}: identical trace, identical tokens");
        assert!(!shf.truncated && !nhf.truncated);
        assert!(
            shf.tokens_per_sec >= nhf.tokens_per_sec,
            "tp={tp}: SHF {} tok/s < NHF {} tok/s",
            shf.tokens_per_sec,
            nhf.tokens_per_sec
        );
        assert!(
            shf.decode_l2_hit_pct >= nhf.decode_l2_hit_pct,
            "tp={tp}: SHF decode L2 {:.2}% < NHF {:.2}%",
            shf.decode_l2_hit_pct,
            nhf.decode_l2_hit_pct
        );
    }
}

#[test]
fn sharding_shrinks_prefill_time_on_the_same_trace() {
    // Each device prefills H_Q/tp heads, so the summed prefill charge —
    // all-gather included — must drop when the deployment shards. (Total
    // tokens served are identical, so this is the lever that moves
    // tokens/s; the strict TP-8 >= TP-1 throughput ordering on the real
    // MI300X sweep is asserted by benches/cluster_scaling.rs.)
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let cfg = ServeConfig {
        prefill_lengths: vec![8192, 16384],
        ..small_serve()
    };
    let (c1, p1) = tp_cluster(&topo, &cfg, 1);
    let (c2, p2) = tp_cluster(&topo, &cfg, 2);
    let tp1 = serve_decode_cluster_with(&driver, &c1, &p1, &cfg, Policy::SwizzledHeadFirst);
    let tp2 = serve_decode_cluster_with(&driver, &c2, &p2, &cfg, Policy::SwizzledHeadFirst);
    assert_eq!(tp1.tokens, tp2.tokens);
    assert!(
        tp2.prefill_sec < tp1.prefill_sec,
        "tp=2 prefill {} s should be below tp=1 {} s",
        tp2.prefill_sec,
        tp1.prefill_sec
    );
    // Both runs consulted the advisor per distinct geometry.
    assert!(tp2.advisor_consults >= 1);
    assert_eq!(tp2.advisor_consults, tp2.distinct_geometries);
}

#[test]
fn golden_whole_prompt_chunks_reproduce_monolithic_cluster_serve() {
    // The cluster half of the golden-equivalence pin: on a real tp=2
    // shard plan, a chunk size covering every prompt degenerates to one
    // full-prompt chunk per session — the identical sharded jobs plus
    // the identical all-gather — so the cluster serving JSON reproduces
    // the chunking-off run byte-for-byte at 1 and 8 driver workers.
    let topo = fast_topo();
    let off = small_serve();
    let max_prompt = *off.prefill_lengths.iter().max().unwrap();
    let one_chunk = ServeConfig { chunk_tokens: max_prompt, ..small_serve() };
    let (cluster, plan) = tp_cluster(&topo, &off, 2);
    for threads in [1usize, 8] {
        let mono = serve_decode_cluster_with(
            &SimDriver::new(threads),
            &cluster,
            &plan,
            &off,
            Policy::SwizzledHeadFirst,
        );
        let chunked = serve_decode_cluster_with(
            &SimDriver::new(threads),
            &cluster,
            &plan,
            &one_chunk,
            Policy::SwizzledHeadFirst,
        );
        assert_eq!(
            mono.to_json().render(),
            chunked.to_json().render(),
            "{threads} workers: one-chunk cluster serve diverged from monolithic"
        );
    }
}

#[test]
fn golden_sharing_disabled_reproduces_historical_cluster_serve() {
    // The cluster half of the paged-KV golden pin (docs/KVCACHE.md): on
    // a real tp=2 shard plan, either pool knob at 0 leaves the pool
    // disengaged, so the cluster serving JSON reproduces the pool-free
    // run byte-for-byte at 1 and 8 driver workers.
    let topo = fast_topo();
    let base = small_serve();
    let blocks_only = ServeConfig { kv_block_tokens: 256, ..small_serve() };
    let share_only = ServeConfig { prefix_share_pct: 80.0, ..small_serve() };
    let (cluster, plan) = tp_cluster(&topo, &base, 2);
    for threads in [1usize, 8] {
        let driver = SimDriver::new(threads);
        let want = serve_decode_cluster_with(
            &driver,
            &cluster,
            &plan,
            &base,
            Policy::SwizzledHeadFirst,
        )
        .to_json()
        .render();
        for (name, cfg) in [("blocks_only", &blocks_only), ("share_only", &share_only)] {
            assert!(!cfg.kv_pool_enabled(), "{name}: one knob must not enable the pool");
            let got = serve_decode_cluster_with(
                &driver,
                &cluster,
                &plan,
                cfg,
                Policy::SwizzledHeadFirst,
            )
            .to_json()
            .render();
            assert_eq!(
                got, want,
                "{threads} workers: {name} diverged from the pool-free cluster serve JSON"
            );
        }
    }
}

#[test]
fn golden_colocated_disagg_reproduces_cluster_serve_byte_for_byte() {
    // The cluster half of the disaggregation golden pin (docs/DISAGG.md
    // §2): a colocated DisaggConfig with `decode_devices = 2` runs the
    // historical tensor-parallel cluster path on a homogeneous tp=2
    // cluster with the default interconnect — the DisaggStats JSON
    // (extras absent) must reproduce the `cluster` serve JSON
    // byte-for-byte at 1 and 8 driver workers. DisaggConfig's default
    // link (128 GB/s, 1 µs) is bitwise the cluster module's default, so
    // the all-gather charges agree exactly.
    let topo = fast_topo();
    let base = small_serve();
    let cfg = DisaggConfig {
        serve: base.clone(),
        prefill_devices: 0,
        decode_devices: 2,
        interactive_pct: 0.0,
        ttft_slo_ms: 0.0,
        ..DisaggConfig::default()
    };
    assert!(cfg.colocated());
    let (cluster, plan) = tp_cluster(&topo, &base, 2);
    for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
        for threads in [1usize, 8] {
            let driver = SimDriver::new(threads);
            let want = serve_decode_cluster_with(&driver, &cluster, &plan, &base, policy)
                .to_json()
                .render();
            let got = serve_decode_disagg_with(&driver, &topo, &cfg, policy);
            assert!(got.extras.is_none(), "colocated run must not grow extras");
            assert_eq!(
                got.to_json().render(),
                want,
                "{policy} @ {threads} workers: colocated x2 disagg diverged from the \
                 historical cluster serve JSON"
            );
        }
    }
}

#[test]
fn shared_cluster_serve_credits_tokens_and_keeps_shf_affinity_home() {
    // Sharing composes with sharding: on a tp=2 plan the pool-enabled
    // run conserves prompt tokens across the charged/credited split,
    // credits a strictly positive shared volume at 100% share, and the
    // per-KV-head placement rule keeps every inserted block home under
    // SwizzledHeadFirst (each device's shard-local swizzle pins a KV
    // head's whole decode stream to one XCD), while NaiveHeadFirst
    // scatters blocks round-robin and scores strictly lower.
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let base = small_serve();
    let shared = ServeConfig {
        kv_block_tokens: 256,
        prefix_share_pct: 100.0,
        ..small_serve()
    };
    let (cluster, plan) = tp_cluster(&topo, &base, 2);
    let mono =
        serve_decode_cluster_with(&driver, &cluster, &plan, &base, Policy::SwizzledHeadFirst);
    let shf =
        serve_decode_cluster_with(&driver, &cluster, &plan, &shared, Policy::SwizzledHeadFirst);
    let nhf = serve_decode_cluster_with(&driver, &cluster, &plan, &shared, Policy::NaiveHeadFirst);
    assert!(!mono.truncated && !shf.truncated && !nhf.truncated);
    assert_eq!(shf.tokens, mono.tokens, "identical trace, identical decode tokens");
    assert!(shf.kv_shared_tokens > 0, "100%-share must credit resident prefixes");
    assert_eq!(
        shf.prefill_tokens + shf.kv_shared_tokens,
        mono.prefill_tokens,
        "charged + credited must cover every prompt token exactly once"
    );
    assert!(
        shf.prefill_sec < mono.prefill_sec,
        "credited prefixes must cut prefill wall-clock ({} >= {})",
        shf.prefill_sec,
        mono.prefill_sec
    );
    assert_eq!(shf.kv_xcd_affinity_pct, 100.0, "SHF keeps every inserted block home");
    assert!(
        nhf.kv_xcd_affinity_pct < shf.kv_xcd_affinity_pct,
        "NHF scatters blocks across XCDs ({} >= {})",
        nhf.kv_xcd_affinity_pct,
        shf.kv_xcd_affinity_pct
    );
}

#[test]
fn chunked_tp1_cluster_serve_is_byte_identical_to_single_device() {
    // The executor generalization holds under chunking too: a tp=1
    // cluster prices chunked-prefill launches identically to the
    // single-device path (same jobs, fraction 1.0-free math, zero
    // all-gather).
    let topo = fast_topo();
    let cfg = ServeConfig { chunk_tokens: 512, step_token_budget: 1024, ..small_serve() };
    let (cluster, plan) = tp_cluster(&topo, &cfg, 1);
    for threads in [1usize, 8] {
        let single =
            serve_decode_with(&SimDriver::new(threads), &topo, &cfg, Policy::SwizzledHeadFirst);
        let clustered = serve_decode_cluster_with(
            &SimDriver::new(threads),
            &cluster,
            &plan,
            &cfg,
            Policy::SwizzledHeadFirst,
        );
        assert_eq!(
            single.to_json().render(),
            clustered.to_json().render(),
            "{threads} workers: chunked tp=1 cluster diverged from single-device"
        );
    }
}

#[test]
fn chunked_cluster_serve_conserves_tokens_and_cuts_prefill() {
    // Chunking composes with sharding: the tp=2 chunked run serves the
    // identical tokens, prefills every prompt token exactly once, and
    // undercuts the monolithic tp=2 prefill wall-clock.
    let driver = SimDriver::new(4);
    let topo = fast_topo();
    let mono_cfg = small_serve();
    let chunked_cfg = ServeConfig { chunk_tokens: 512, step_token_budget: 1024, ..small_serve() };
    let (cluster, plan) = tp_cluster(&topo, &mono_cfg, 2);
    let mono =
        serve_decode_cluster_with(&driver, &cluster, &plan, &mono_cfg, Policy::SwizzledHeadFirst);
    let chunked = serve_decode_cluster_with(
        &driver,
        &cluster,
        &plan,
        &chunked_cfg,
        Policy::SwizzledHeadFirst,
    );
    assert!(!mono.truncated && !chunked.truncated);
    assert_eq!(chunked.tokens, mono.tokens);
    assert_eq!(chunked.prefill_tokens, mono.prefill_tokens);
    assert!(
        chunked.prefill_sec < mono.prefill_sec,
        "tp=2 chunked prefill {} s >= monolithic {} s",
        chunked.prefill_sec,
        mono.prefill_sec
    );
    assert!(chunked.ttft_p50_ms > 0.0 && chunked.ttft_p50_ms <= chunked.ttft_p99_ms);
}

#[test]
fn strided_and_contiguous_plans_price_identically_when_homogeneous() {
    // The two strategies place different head IDS on each device, but on
    // a homogeneous cluster every device runs the same shard-local
    // geometry either way — so the priced stats agree bit-for-bit. (The
    // strategies exist for heterogeneous/affinity setups; this pins that
    // choosing one is free under the balanced model.)
    let topo = fast_topo();
    let cfg = small_serve();
    let cluster = ClusterTopology::node_of(&topo, 2);
    let cont = ShardPlan::new(&cfg.base_geometry(), 2, ShardStrategy::Contiguous).unwrap();
    let strd = ShardPlan::new(&cfg.base_geometry(), 2, ShardStrategy::Strided).unwrap();
    assert_ne!(cont.query_heads(0), strd.query_heads(0), "layouts really differ");
    let driver = SimDriver::new(2);
    let a = serve_decode_cluster_with(&driver, &cluster, &cont, &cfg, Policy::SwizzledHeadFirst);
    let b = serve_decode_cluster_with(&driver, &cluster, &strd, &cfg, Policy::SwizzledHeadFirst);
    assert_eq!(a.to_json().render(), b.to_json().render());
}

#[test]
fn golden_empty_fault_plan_reproduces_cluster_serve_byte_for_byte() {
    // The fault-injection golden pin (docs/SERVING.md §9): an empty
    // plan delegates straight to the historical cluster path — the
    // JSON matches byte-for-byte (no trailing "faults" key) at 1 and 8
    // driver workers, so enabling the fault machinery cost the
    // fault-free deployment nothing.
    let topo = fast_topo();
    let cfg = small_serve();
    let (cluster, plan) = tp_cluster(&topo, &cfg, 2);
    for policy in [Policy::SwizzledHeadFirst, Policy::NaiveHeadFirst] {
        for threads in [1usize, 8] {
            let driver = SimDriver::new(threads);
            let want = serve_decode_cluster_with(&driver, &cluster, &plan, &cfg, policy)
                .to_json()
                .render();
            let got =
                serve_decode_faulty_with(&driver, &topo, 2, &cfg, policy, &FaultPlan::default());
            assert!(got.faults.is_none(), "an empty plan must not grow fault extras");
            assert_eq!(
                got.to_json().render(),
                want,
                "{policy} @ {threads} workers: empty fault plan diverged from the \
                 historical cluster serve JSON"
            );
        }
    }
}

#[test]
fn faulty_cluster_serve_is_byte_identical_across_worker_counts() {
    // Determinism holds through evictions and resharding: the same
    // non-empty plan renders identical JSON at 1 and 8 driver workers.
    let topo = fast_topo();
    let cfg = ServeConfig {
        prefill_lengths: vec![512],
        decode_tokens: vec![64],
        ..small_serve()
    };
    let clean = serve_decode_faulty_with(
        &SimDriver::new(1),
        &topo,
        2,
        &cfg,
        Policy::SwizzledHeadFirst,
        &FaultPlan::default(),
    );
    let t = clean.serve.sim_sec;
    let plan = FaultPlan::parse(&format!("1:{}:{}", 0.3 * t, 0.6 * t)).unwrap();
    let serial = serve_decode_faulty_with(
        &SimDriver::new(1),
        &topo,
        2,
        &cfg,
        Policy::SwizzledHeadFirst,
        &plan,
    );
    let parallel = serve_decode_faulty_with(
        &SimDriver::new(8),
        &topo,
        2,
        &cfg,
        Policy::SwizzledHeadFirst,
        &plan,
    );
    assert_eq!(
        serial.to_json().render(),
        parallel.to_json().render(),
        "faulty cluster serve diverged between 1 and 8 workers"
    );
}
