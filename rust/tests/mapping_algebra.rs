//! Golden pins of the composed mapping algebra (docs/TUNING.md): the
//! legacy `Policy` variants and their algebra points are the *same
//! mapping*, all the way through the simulation driver.
//!
//! Three contracts:
//!   * canonicalization — legacy-plane spec strings parse back onto the
//!     legacy enum variants (so cache keys, figures, and goldens never
//!     see a second identity for the paper's four policies), and every
//!     canonical point's name round-trips through `FromStr`;
//!   * report equivalence — a directly-constructed `Policy::Composed`
//!     legacy point (bypassing `from_spec` canonicalization) produces
//!     byte-identical SimReport JSON to the named variant for forward,
//!     backward, and split-KV decode, on the serial driver and the
//!     8-worker pool, differing only in the policy-name field;
//!   * bijectivity — every policy the tuner can search decodes its grid
//!     as a permutation, for divisible and non-divisible head counts,
//!     on prefill and split-KV decode grids alike.

use std::collections::BTreeSet;
use std::str::FromStr;

use numa_attn::attn::{AttnConfig, KernelKind, WorkItem};
use numa_attn::coordinator::{search_space, TuneKernel};
use numa_attn::driver::{SimDriver, SimJob};
use numa_attn::mapping::{Mapping, Policy, ALL_POLICIES};
use numa_attn::sim::SimConfig;
use numa_attn::topology::{presets, Topology};

fn small_topo() -> Topology {
    Topology {
        name: "tiny".into(),
        num_xcds: 4,
        cus_per_xcd: 4,
        l2_bytes_per_xcd: 512 * 1024,
        ..presets::mi300x()
    }
}

#[test]
fn legacy_plane_spec_strings_canonicalize_onto_the_enum_variants() {
    for &p in &ALL_POLICIES {
        let spec_name = p.spec().name();
        let parsed = Policy::from_str(&spec_name).unwrap();
        assert_eq!(parsed, p, "{spec_name} must parse onto the legacy variant");
        assert_eq!(Policy::from_spec(p.spec()), p);
        // The canonical identity is the historical snake_case name, not
        // the spec string — figures and cache keys are untouched.
        assert_ne!(parsed.name(), spec_name);
    }
    for q in Policy::all_canonical() {
        assert_eq!(Policy::from_str(&q.name()).unwrap(), q, "{} must round-trip", q.name());
    }
}

/// Render a report list, rewriting the policy-name field from the
/// composed spec string to the legacy name so the remaining bytes can
/// be compared exactly.
fn render_as(reports: &[numa_attn::SimReport], from: &Policy, to: &Policy) -> Vec<String> {
    reports
        .iter()
        .map(|r| {
            r.to_json()
                .render()
                .replace(&format!("\"{}\"", from.name()), &format!("\"{}\"", to.name()))
        })
        .collect()
}

#[test]
fn raw_composed_legacy_points_report_byte_identically_to_the_variants() {
    let topo = small_topo();
    let cfg = AttnConfig {
        block_m: 128,
        block_n: 64,
        causal: true,
        ..AttnConfig::gqa(1, 4, 4, 2048, 128)
    };
    for threads in [1usize, 8] {
        let driver = SimDriver::new(threads);
        for &legacy in &ALL_POLICIES {
            let raw = Policy::Composed(legacy.spec());
            let jobs = |p: Policy| {
                vec![
                    SimJob::forward(&topo, &cfg, SimConfig::forward(p)),
                    SimJob::backward(&topo, &cfg, SimConfig::backward(p)),
                    SimJob::decode(&topo, &cfg, SimConfig::decode(p, 2)),
                ]
            };
            let want = render_as(&driver.run_all(jobs(legacy)), &legacy, &legacy);
            let got = render_as(&driver.run_all(jobs(raw)), &raw, &legacy);
            assert_eq!(got, want, "{} diverged at {threads} worker(s)", raw.name());
        }
    }
}

fn assert_bijective(m: &Mapping) {
    let mut seen = BTreeSet::new();
    for s in 0..m.grid_size() {
        let w = m.decode(s);
        assert!((w.z as usize) < m.batch, "{}: batch out of range", m.policy.name());
        assert!((w.h as usize) < m.heads, "{}: head out of range", m.policy.name());
        assert!((w.b as usize) < m.blocks, "{}: block out of range", m.policy.name());
        assert!(
            seen.insert((w.z, w.h, w.b)),
            "{}: slot {s} collides at ({}, {}, {})",
            m.policy.name(),
            w.z,
            w.h,
            w.b
        );
    }
    assert_eq!(seen.len(), m.grid_size());
}

#[test]
fn every_searched_policy_decodes_a_bijection() {
    let topo = small_topo();
    // Divisible (h_q = 8 over 4 XCDs) and non-divisible (h_q = 6) head
    // counts; the non-divisible space is the rr-* half of the algebra.
    for cfg in [AttnConfig::gqa(2, 8, 4, 2048, 128), AttnConfig::mha(2, 6, 2048, 128)] {
        let kernels = [
            (TuneKernel::Forward, KernelKind::Forward),
            (TuneKernel::Backward, KernelKind::BwdDkDv),
            (TuneKernel::Decode { num_splits: 4 }, KernelKind::DecodeSplitKv { num_splits: 4 }),
        ];
        for (tk, kk) in kernels {
            let space = search_space(&topo, &cfg, tk);
            assert!(!space.is_empty());
            for p in space {
                let m = Mapping::for_kernel(p, &cfg, kk, topo.num_xcds).unwrap();
                assert_bijective(&m);
            }
        }
    }
}

#[test]
fn sawtooth_and_grouped_points_change_the_schedule_but_not_the_work() {
    // The two extra axes must actually *do* something on the grids they
    // target (otherwise search_space's pruning claim is vacuous), while
    // preserving each head's block set exactly.
    let cfg = AttnConfig::gqa(1, 8, 4, 2048, 128);
    let lin = Policy::from_str("swz-head-lin-inherit").unwrap();
    let saw = Policy::from_str("swz-head-saw-inherit").unwrap();
    let kk = KernelKind::Forward;
    let a = Mapping::for_kernel(lin, &cfg, kk, 4).unwrap().decode_all();
    let b = Mapping::for_kernel(saw, &cfg, kk, 4).unwrap().decode_all();
    assert_ne!(
        a.iter().map(|w| (w.z, w.h, w.b)).collect::<Vec<_>>(),
        b.iter().map(|w| (w.z, w.h, w.b)).collect::<Vec<_>>(),
        "sawtooth must reorder the schedule"
    );
    // Same (head -> block multiset) under both orders.
    let sets = |ws: &[WorkItem]| {
        let mut m: std::collections::BTreeMap<u32, BTreeSet<u32>> = Default::default();
        for w in ws {
            m.entry(w.h).or_default().insert(w.b);
        }
        m
    };
    assert_eq!(sets(&a), sets(&b));

    // Grouped: identity off split grids, head-first traversal on them.
    let blk_inherit = Policy::from_str("rr-block-lin-inherit").unwrap();
    let blk_grouped = Policy::from_str("rr-block-lin-grouped").unwrap();
    let prefill_a = Mapping::for_kernel(blk_inherit, &cfg, kk, 4).unwrap().decode_all();
    let prefill_b = Mapping::for_kernel(blk_grouped, &cfg, kk, 4).unwrap().decode_all();
    assert_eq!(
        prefill_a.iter().map(|w| (w.z, w.h, w.b)).collect::<Vec<_>>(),
        prefill_b.iter().map(|w| (w.z, w.h, w.b)).collect::<Vec<_>>(),
        "grouped must be a no-op on prefill grids"
    );
    let dk = KernelKind::DecodeSplitKv { num_splits: 4 };
    let split_g = Mapping::for_kernel(blk_grouped, &cfg, dk, 4).unwrap();
    let head_first = Mapping::for_kernel(Policy::NaiveHeadFirst, &cfg, dk, 4).unwrap();
    assert_eq!(
        split_g.decode_all().iter().map(|w| (w.z, w.h, w.b)).collect::<Vec<_>>(),
        head_first.decode_all().iter().map(|w| (w.z, w.h, w.b)).collect::<Vec<_>>(),
        "grouped must force head-first split placement on decode grids"
    );
}
