//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored micro-crate provides the (small) subset of anyhow's API that
//! `numa-attn` uses: [`Error`], [`Result`], the [`Context`] extension
//! trait for `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!`
//! macros. Semantics match anyhow where it matters here:
//!
//! * `{}` displays the outermost message, `{:#}` the whole cause chain
//!   joined by `": "`, `{:?}` the message plus a `Caused by:` list;
//! * `?` converts any `std::error::Error + Send + Sync + 'static`;
//! * `Error` deliberately does NOT implement `std::error::Error`, which
//!   is what lets the blanket `From`/`Context` impls coexist with the
//!   `Result<T, Error>` impls (the same trick the real crate uses).
//!
//! Causes are flattened to strings at conversion time — downcasting is
//! not supported (nothing in this repo downcasts).

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened error: an outermost message plus its cause chain.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (mirrors `anyhow::Error::context`).
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, or from any single
/// `Display` expression (`anyhow!(msg_string)`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "loading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing field");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{:#}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{:#}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
        let s = String::from("from a string expr");
        let e = anyhow!(s);
        assert_eq!(format!("{e}"), "from a string expr");
        let who = "inline";
        let e = anyhow!("caught {who}");
        assert_eq!(format!("{e}"), "caught inline");
    }
}
