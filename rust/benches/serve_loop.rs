//! Continuous-batching decode serving bench (docs/SERVING.md): runs the
//! serving sweep on the real MI300X topology and asserts the end-to-end
//! payoff of the paper's mapping in the regime that dominates production
//! traffic.
//!
//! Reproduction targets:
//! * SwizzledHeadFirst's decode tokens/s >= NaiveHeadFirst's on every
//!   sweep row — including the chunked-prefill rows (the `serve`
//!   figure's headline ordering survives mixed-step scheduling);
//! * every row actually serves tokens (no degenerate zero-throughput
//!   scenarios);
//! * chunked prefill beats monolithic prefill where it claims to: on the
//!   same trace the chunked twin of a sweep scenario serves the same
//!   tokens at no lower throughput with a lower TTFT p99 (docs/SERVING.md
//!   §6);
//! * the loop leans on the report cache: hundreds of step launches
//!   resolve to far fewer engine runs.

mod common;

use numa_attn::coordinator::{serve_decode_with, serve_scenarios};
use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let driver = common::bench_driver();
    let topo = common::topo();
    let quick = !common::full_sweep();

    let t0 = std::time::Instant::now();
    let fig = figures::serve_fig(&driver, &topo, quick);
    let dt = t0.elapsed();
    println!("{}", fig.render());

    for row in &fig.rows {
        let shf = fig.value(&row.label, Policy::SwizzledHeadFirst).unwrap();
        let nhf = fig.value(&row.label, Policy::NaiveHeadFirst).unwrap();
        common::check(
            shf >= nhf,
            &format!("{}: SHF ({shf:.0} tok/s) >= NHF ({nhf:.0} tok/s)", row.label),
        );
        common::check(shf > 0.0, &format!("{}: throughput is non-degenerate", row.label));
    }

    // The chunked-prefill claim, on the sweep's own monolithic/chunked
    // scenario twin (identical trace — only the step composition
    // differs): equal tokens, at-least-equal throughput, better TTFT
    // tail. Runs through the same driver, so the figure above already
    // paid for every geometry this re-prices.
    let scenarios = serve_scenarios(quick);
    let mono = scenarios
        .iter()
        .find(|s| s.label == "llama3-70b arr=120/s cap=8")
        .expect("monolithic twin in the sweep");
    let chunked = scenarios
        .iter()
        .find(|s| s.label.starts_with("llama3-70b chunked(1k/2k)"))
        .expect("chunked twin in the sweep");
    let m = serve_decode_with(&driver, &topo, &mono.cfg, Policy::SwizzledHeadFirst);
    let c = serve_decode_with(&driver, &topo, &chunked.cfg, Policy::SwizzledHeadFirst);
    common::check(
        c.tokens == m.tokens && c.prefill_tokens == m.prefill_tokens,
        &format!(
            "chunked twin serves the identical work ({} tok / {} prompt tok)",
            c.tokens, c.prefill_tokens
        ),
    );
    common::check(
        c.ttft_p99_ms <= m.ttft_p99_ms,
        &format!(
            "chunked TTFT p99 ({:.3} ms) <= monolithic ({:.3} ms)",
            c.ttft_p99_ms, m.ttft_p99_ms
        ),
    );
    // "Equal throughput": chunking redistributes prefill, it must not
    // buy its TTFT win by starving decode (a few percent of slack
    // covers the extra decode launches of the streaming lead-ins).
    common::check(
        c.tokens_per_sec >= 0.95 * m.tokens_per_sec,
        &format!(
            "chunked throughput ({:.0} tok/s) within 5% of monolithic ({:.0} tok/s)",
            c.tokens_per_sec, m.tokens_per_sec
        ),
    );

    // The paged-KV prefix-sharing claim (docs/KVCACHE.md), on the
    // sweep's 80%-shared scenario against its sharing-disabled twin
    // (`kv_block_tokens = 0` disengages the pool; the trace is identical
    // because the share draw rides its own RNG stream): credited
    // prefixes must cut the first-token tail and raise throughput, and
    // the NUMA placement rule must keep SwizzledHeadFirst's inserted
    // blocks home where NaiveHeadFirst scatters them.
    let shared = scenarios
        .iter()
        .find(|s| s.label == "llama3-70b 80%-shared arr=120/s cap=8")
        .expect("80%-shared scenario in the sweep");
    let mut unshared_cfg = shared.cfg.clone();
    unshared_cfg.kv_block_tokens = 0;
    let sh = serve_decode_with(&driver, &topo, &shared.cfg, Policy::SwizzledHeadFirst);
    let un = serve_decode_with(&driver, &topo, &unshared_cfg, Policy::SwizzledHeadFirst);
    let sh_nhf = serve_decode_with(&driver, &topo, &shared.cfg, Policy::NaiveHeadFirst);
    common::check(
        sh.kv_shared_tokens > 0 && sh.prefill_tokens + sh.kv_shared_tokens == un.prefill_tokens,
        &format!(
            "sharing credits tokens and conserves the prompt total ({} + {} == {})",
            sh.prefill_tokens, sh.kv_shared_tokens, un.prefill_tokens
        ),
    );
    common::check(
        sh.ttft_p99_ms <= un.ttft_p99_ms,
        &format!(
            "80%-shared TTFT p99 ({:.3} ms) <= sharing-disabled ({:.3} ms)",
            sh.ttft_p99_ms, un.ttft_p99_ms
        ),
    );
    common::check(
        sh.tokens_per_sec >= un.tokens_per_sec,
        &format!(
            "80%-shared throughput ({:.0} tok/s) >= sharing-disabled ({:.0} tok/s)",
            sh.tokens_per_sec, un.tokens_per_sec
        ),
    );
    common::check(
        sh.kv_xcd_affinity_pct >= sh_nhf.kv_xcd_affinity_pct,
        &format!(
            "SHF KV-block XCD affinity ({:.1}%) >= NHF ({:.1}%)",
            sh.kv_xcd_affinity_pct, sh_nhf.kv_xcd_affinity_pct
        ),
    );

    let cstats = driver.cache().counters();
    common::check(
        cstats.hits > cstats.misses,
        &format!(
            "the serving loop re-uses the report cache (hits {} > misses {})",
            cstats.hits, cstats.misses
        ),
    );
    println!(
        "[bench] serve: {} scenario row(s) in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        fig.rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        cstats.hits,
        cstats.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full sweep" } else { "full sweep" }
    );
}
