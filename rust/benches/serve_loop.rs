//! Continuous-batching decode serving bench (docs/SERVING.md): runs the
//! serving sweep on the real MI300X topology and asserts the end-to-end
//! payoff of the paper's mapping in the regime that dominates production
//! traffic.
//!
//! Reproduction targets:
//! * SwizzledHeadFirst's decode tokens/s >= NaiveHeadFirst's on every
//!   sweep row (the `serve` figure's headline ordering);
//! * every row actually serves tokens (no degenerate zero-throughput
//!   scenarios);
//! * the loop leans on the report cache: hundreds of step launches
//!   resolve to far fewer engine runs.

mod common;

use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let driver = common::bench_driver();
    let topo = common::topo();
    let quick = !common::full_sweep();

    let t0 = std::time::Instant::now();
    let fig = figures::serve_fig(&driver, &topo, quick);
    let dt = t0.elapsed();
    println!("{}", fig.render());

    for row in &fig.rows {
        let shf = fig.value(&row.label, Policy::SwizzledHeadFirst).unwrap();
        let nhf = fig.value(&row.label, Policy::NaiveHeadFirst).unwrap();
        common::check(
            shf >= nhf,
            &format!("{}: SHF ({shf:.0} tok/s) >= NHF ({nhf:.0} tok/s)", row.label),
        );
        common::check(shf > 0.0, &format!("{}: throughput is non-degenerate", row.label));
    }

    let c = driver.cache().counters();
    common::check(
        c.hits > c.misses,
        &format!(
            "the serving loop re-uses the report cache (hits {} > misses {})",
            c.hits, c.misses
        ),
    );
    println!(
        "[bench] serve: {} scenario row(s) in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        fig.rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        c.hits,
        c.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full sweep" } else { "full sweep" }
    );
}
