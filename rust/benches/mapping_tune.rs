//! Mapping-autotuner bench (docs/TUNING.md): run the default tuning
//! sweep on the real MI300X topology and assert the headline claim of
//! the composed mapping algebra.
//!
//! Reproduction targets:
//! * the searched mapping never loses to the paper's
//!   swizzled_head_first: on EVERY sweep row the tuned time is <= the
//!   SHF baseline time (structural — SHF is in the search space and
//!   ranking is a strict argmin — but asserted end-to-end here);
//! * the algebra buys something real beyond the four named policies:
//!   on SOME sweep row a composed point strictly beats SHF (the
//!   order-sensitive regimes — causal forward, oversubscribed split-KV
//!   decode — are exactly why the sweep rows are chosen);
//! * re-tuning is free: the whole sweep re-runs against a warm report
//!   cache with zero new engine runs.
//!
//! Writes the pinned `bench-v1` trajectory `BENCH_tune.json` at the
//! repo root, validated by `scripts/check_bench_json.py`.

mod common;

use numa_attn::coordinator::{default_requests, tune_with, SearchMode, TuneRow};
use numa_attn::util::bench::Harness;

fn main() {
    let driver = common::bench_driver();
    let topo = common::topo();
    let quick = !common::full_sweep();
    let mut h = Harness::new("tune");

    let requests = default_requests(quick);
    let t0 = std::time::Instant::now();
    let mut rows: Vec<TuneRow> = Vec::new();
    for req in &requests {
        // The warmup iteration pays the engine runs; the timed
        // iterations measure the memoized re-tune path.
        let mut row = None;
        h.run(&format!("tune: {}", req.label), 3, || {
            row = Some(tune_with(&driver, &topo, req, SearchMode::Exhaustive));
        });
        let row = row.expect("tuning ran");
        h.metric("speedup_vs_shf", row.speedup());
        h.metric("tuned_ms", row.best_sec * 1e3);
        h.metric("shf_ms", row.baseline_sec * 1e3);
        h.metric("candidates", row.candidates.len() as f64);
        println!(
            "[tune] {:<32} best {:<24} {:>9.4} ms  vs {} {:>9.4} ms  ({:.3}x, {} candidates)",
            row.label,
            row.best.name(),
            row.best_sec * 1e3,
            row.baseline.name(),
            row.baseline_sec * 1e3,
            row.speedup(),
            row.candidates.len(),
        );
        rows.push(row);
    }
    let dt = t0.elapsed();

    // Never-worse, on every row (the bench-level restatement of the
    // tuner's structural guarantee).
    for row in &rows {
        common::check(
            row.speedup() >= 1.0,
            &format!(
                "{}: tuned {} ({:.4} ms) never loses to {} ({:.4} ms)",
                row.label,
                row.best.name(),
                row.best_sec * 1e3,
                row.baseline.name(),
                row.baseline_sec * 1e3
            ),
        );
    }
    // Strictly-better, on some row: the composed algebra must earn its
    // twelve extra points somewhere in the sweep.
    let best_row =
        rows.iter().max_by(|a, b| a.speedup().partial_cmp(&b.speedup()).unwrap()).unwrap();
    common::check(
        best_row.speedup() > 1.0,
        &format!(
            "some searched mapping strictly beats swizzled_head_first \
             (best: {} on '{}', {:.4}x)",
            best_row.best.name(),
            best_row.label,
            best_row.speedup()
        ),
    );

    // Memoization: a full re-tune of the sweep touches only the cache.
    let misses_before = driver.cache().counters().misses;
    for req in &requests {
        tune_with(&driver, &topo, req, SearchMode::Exhaustive);
    }
    let misses_after = driver.cache().counters().misses;
    common::check(
        misses_after == misses_before,
        &format!("re-tuning the sweep is free ({misses_before} misses before and after)"),
    );

    let cstats = driver.cache().counters();
    println!(
        "[bench] tune: {} sweep row(s) in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        cstats.hits,
        cstats.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full sweep" } else { "full sweep" }
    );

    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_tune.json");
    h.write_json(&path).expect("write BENCH_tune.json");
    println!("[perf] trajectory written to {}", path.display());
}
