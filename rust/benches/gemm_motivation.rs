//! Paper Sec. 1 motivating claim: chiplet-aware workgroup swizzling
//! lifted GEMM L2 hit rates from 43% to 92% on MI300X (AMD Tensile).

mod common;

use numa_attn::figures;

fn main() {
    let topo = common::topo();
    let t0 = std::time::Instant::now();
    let fig = figures::gemm_motivation(&topo);
    println!("{}", fig.render());
    println!("[bench] gemm: {:.2} s", t0.elapsed().as_secs_f64());

    let naive = fig.rows[0].values[0].1;
    let swz = fig.rows[0].values[1].1;
    common::check(
        naive < 60.0,
        &format!("naive GEMM mapping has poor L2 hit rate ({naive:.1}%)"),
    );
    common::check(
        swz > 80.0,
        &format!("chiplet-swizzled GEMM exceeds 80% ({swz:.1}%)"),
    );
    common::check(
        swz - naive > 25.0,
        &format!("swizzle improves hit rate by a large margin (+{:.1} pts)", swz - naive),
    );
}
