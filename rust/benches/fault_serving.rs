//! Cluster fault-injection bench (docs/SERVING.md §9): serves the
//! widest-TP cluster deployment on the real MI300X topology through a
//! planned mid-run device outage and asserts the headline resilience
//! claims.
//!
//! Reproduction targets:
//! * the outage actually fires and the shard plan re-forms — at least
//!   one rebalance per health transition, with the evicted sessions
//!   re-queued through the router;
//! * exactly-once serving across fail/recover: no session is lost or
//!   double-served (every admitted session completes exactly once) and
//!   the run is not truncated;
//! * the degraded interval is visibly slower than healthy full-width
//!   serving (degraded busy-time tokens/s < healthy), and after the
//!   device returns the post-recovery window restores at least 95% of
//!   the pre-failure rate;
//! * an empty fault plan takes the historical cluster path (no fault
//!   extras recorded), so the fault layer is pay-for-what-you-use.
//!
//! The hard-asserted probe serves a decode-dominated lockstep workload
//! (simultaneous arrivals, uniform decode budgets) so the pre-failure
//! and post-recovery windows compare full batches against full batches;
//! the `serve_burst` figure and the fault report printed alongside show
//! the same machinery on the mixed cluster-sweep scenarios.
//!
//! Writes the pinned `bench-v1` trajectory `BENCH_faults.json` at the
//! repo root, validated by `scripts/check_bench_json.py`.

mod common;

use numa_attn::coordinator::{
    cluster_scenarios, fault_report, serve_decode_faulty_with, FaultEvent, FaultPlan, FaultSpec,
    ServeConfig,
};
use numa_attn::figures;
use numa_attn::mapping::Policy;
use numa_attn::util::bench::Harness;
use numa_attn::workload::sweeps::CLUSTER_TP;

fn main() {
    let driver = common::bench_driver();
    let topo = common::topo();
    let quick = !common::full_sweep();
    let mut h = Harness::new("faults");

    // The figure panel (tokens/s per fault window + TTFT p99, healthy
    // vs degraded, every applicable policy). The driver memoizes
    // per-geometry pricing, so the probe runs below re-use the cache
    // this fill pays for.
    let t0 = std::time::Instant::now();
    let fig = figures::serve_burst_fig(&driver, &topo, quick);
    let dt = t0.elapsed();
    println!("{}", fig.render());

    // Decode-dominated lockstep probe on the sweep's widest-TP
    // geometry: all sessions arrive at once and carry the same decode
    // budget, so occupancy stays flat until a sharp final drain and the
    // window rates are batch-for-batch comparable.
    let tp = *CLUSTER_TP.last().expect("cluster sweep has TP degrees");
    let sc = cluster_scenarios(quick)
        .into_iter()
        .find(|sc| sc.tp == tp)
        .expect("widest-TP scenario in the sweep");
    let cfg = ServeConfig {
        arrival_per_sec: 1.0e6,
        prefill_lengths: vec![512],
        decode_tokens: vec![256],
        sessions: 8,
        max_active: 8,
        max_steps: 6400,
        ..sc.cfg.clone()
    };

    let mut clean = None;
    h.run("faults: clean full-width serve (SHF)", 2, || {
        clean = Some(serve_decode_faulty_with(
            &driver,
            &topo,
            tp,
            &cfg,
            Policy::SwizzledHeadFirst,
            &FaultPlan::default(),
        ));
    });
    let clean = clean.expect("clean run ran");
    common::check(
        clean.faults.is_none() && !clean.serve.truncated,
        "an empty fault plan takes the historical cluster path (no fault extras)",
    );
    h.metric("tokens_per_sec", clean.serve.tokens_per_sec);
    h.metric("sim_sec", clean.serve.sim_sec);

    // One outage on device 1, timed off the clean run so the degraded
    // interval lands squarely inside the serve.
    let t = clean.serve.sim_sec;
    let plan = FaultPlan {
        events: vec![FaultEvent { device: 1, fail_sec: 0.35 * t, recover_sec: 0.65 * t }],
    };
    println!("[fault] probe plan [{}] over a {:.6} s clean serve", plan.render(), t);

    let mut faulty = None;
    h.run("faults: mid-serve outage, rebalance + recovery (SHF)", 2, || {
        faulty = Some(serve_decode_faulty_with(
            &driver,
            &topo,
            tp,
            &cfg,
            Policy::SwizzledHeadFirst,
            &plan,
        ));
    });
    let faulty = faulty.expect("faulty run ran");
    let f = faulty.faults.as_ref().expect("a non-empty plan records fault extras");
    h.metric("healthy_tokens_per_sec", f.healthy_tokens_per_sec);
    h.metric("degraded_tokens_per_sec", f.degraded_tokens_per_sec);
    h.metric("recovery_ratio", f.recovery_ratio);
    h.metric("rebalances", f.rebalances as f64);
    h.metric("requeued", f.requeued as f64);
    h.metric("events_applied", f.events_applied as f64);
    h.metric("degraded_sec", f.degraded_sec);

    common::check(
        f.events_applied == 2 * plan.events.len(),
        &format!("both health transitions fired ({} applied)", f.events_applied),
    );
    common::check(
        f.rebalances >= 1,
        &format!("the outage re-formed the shard plan ({} rebalance(s))", f.rebalances),
    );
    common::check(
        f.requeued >= 1,
        &format!("the drop evicted and re-queued in-flight sessions ({} re-queued)", f.requeued),
    );
    common::check(
        !faulty.serve.truncated && faulty.serve.sessions_completed == cfg.sessions,
        &format!(
            "no session lost or double-served across fail/recover ({}/{} completed)",
            faulty.serve.sessions_completed, cfg.sessions
        ),
    );
    common::check(
        f.degraded_sec > 0.0 && f.degraded_tokens_per_sec < f.healthy_tokens_per_sec,
        &format!(
            "the degraded interval is visible: {:.0} tok/s degraded < {:.0} tok/s healthy \
             over {:.6} s",
            f.degraded_tokens_per_sec, f.healthy_tokens_per_sec, f.degraded_sec
        ),
    );
    common::check(
        f.recovery_ratio >= 0.95,
        &format!(
            "recovery restores >= 95% of the pre-failure rate (ratio {:.4})",
            f.recovery_ratio
        ),
    );

    // The operator surface: the same engineered plan through the
    // `cluster --faults` report over the widest-TP sweep scenarios.
    // Sweep configs keep their historical step budgets, so this is
    // reported (and sanity-checked) rather than hard-asserted.
    let spec = FaultSpec { events: plan.render(), ..FaultSpec::default() };
    let mut report = None;
    h.run("faults: fault report sweep", 1, || {
        report = Some(fault_report(&driver, &topo, quick, &spec).expect("fault report"));
    });
    let report = report.expect("report ran");
    println!("{}", report.render());
    common::check(
        !report.rows.is_empty() && report.rows.iter().all(|r| !r.stats.is_empty()),
        &format!("every sweep row served under the plan ({} row(s))", report.rows.len()),
    );
    common::check(
        report.rows.iter().all(|r| r.stats.iter().all(|s| s.faults.is_some())),
        "every sweep run recorded fault extras for the non-empty plan",
    );

    let cstats = driver.cache().counters();
    common::check(
        cstats.hits > cstats.misses,
        &format!(
            "the fault loop re-uses the report cache (hits {} > misses {})",
            cstats.hits, cstats.misses
        ),
    );
    println!(
        "[bench] faults: {} figure row(s) in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        fig.rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        cstats.hits,
        cstats.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full sweep" } else { "full sweep" }
    );

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_faults.json");
    h.write_json(&path).expect("write BENCH_faults.json");
    println!("[perf] trajectory written to {}", path.display());
}
