//! Disaggregated prefill/decode serving bench (docs/DISAGG.md): runs
//! the disagg sweep on the real MI300X topology and asserts the
//! headline serving claims of splitting the two phases apart.
//!
//! Reproduction targets:
//! * adding a dedicated prefill pool to a single-device deployment cuts
//!   the interactive first-token tail — disagg 1p+1d interactive TTFT
//!   p99 is strictly below the colocated x1 overall TTFT p99 — while
//!   serving decode tokens at least as fast (the extra device plus
//!   prefill/decode overlap must not lose throughput to the handoff);
//! * the paper's mapping win survives disaggregation: on every sweep
//!   row SwizzledHeadFirst's tokens/s >= NaiveHeadFirst's, and on the
//!   split rows its interactive TTFT p99 is no worse;
//! * the equal-hardware comparison (colocated x2 vs disagg 1p+1d) is
//!   REPORTED for the trade-off table — the paper's claim is about the
//!   interactive tail, not that disaggregation wins raw throughput at
//!   matched device counts, so it carries no hard assertion;
//! * every handoff is priced: the split rows move a positive KV volume
//!   over the interconnect, and the tight-TTFT trace exercises batch
//!   preemption.
//!
//! Writes the pinned `bench-v1` trajectory `BENCH_disagg.json` at the
//! repo root, validated by `scripts/check_bench_json.py`.

mod common;

use numa_attn::coordinator::{serve_decode_disagg_with, DisaggConfig, DisaggStats};
use numa_attn::figures;
use numa_attn::mapping::Policy;
use numa_attn::util::bench::Harness;

fn main() {
    let driver = common::bench_driver();
    let topo = common::topo();
    let quick = !common::full_sweep();
    let mut h = Harness::new("disagg");

    // The sweep figure (every scenario under every applicable policy).
    // The driver memoizes per-geometry pricing, so the per-case runs
    // below re-use the cache this fill pays for.
    let t0 = std::time::Instant::now();
    let fig = figures::disagg_fig(&driver, &topo, quick);
    let dt = t0.elapsed();
    println!("{}", fig.render());

    let report = numa_attn::coordinator::disagg_report(&driver, &topo, quick);
    let disagg_label = "llama3-70b disagg 1p+1d arr=120/s";
    let colo2_label = "llama3-70b colocated x2 arr=120/s";
    let pick = |label: &str, policy: Policy| -> DisaggStats {
        report.stats(label, policy).unwrap_or_else(|| panic!("{label} under {policy}")).clone()
    };

    // Per-row mapping ordering: SHF serves tokens at least as fast as
    // NHF everywhere, and on the split rows (where per-class stats
    // exist) its interactive tail is no worse.
    for row in &report.rows {
        let shf = pick(&row.label, Policy::SwizzledHeadFirst);
        let nhf = pick(&row.label, Policy::NaiveHeadFirst);
        common::check(
            shf.serve.tokens_per_sec >= nhf.serve.tokens_per_sec,
            &format!(
                "{}: SHF ({:.0} tok/s) >= NHF ({:.0} tok/s)",
                row.label, shf.serve.tokens_per_sec, nhf.serve.tokens_per_sec
            ),
        );
        common::check(
            shf.serve.tokens_per_sec > 0.0,
            &format!("{}: throughput is non-degenerate", row.label),
        );
        if let (Some(se), Some(ne)) = (&shf.extras, &nhf.extras) {
            common::check(
                se.interactive.ttft_p99_ms <= ne.interactive.ttft_p99_ms,
                &format!(
                    "{}: SHF interactive TTFT p99 ({:.3} ms) <= NHF ({:.3} ms)",
                    row.label, se.interactive.ttft_p99_ms, ne.interactive.ttft_p99_ms
                ),
            );
        }
    }

    // The headline: against the single-device colocated baseline on the
    // IDENTICAL trace, the split deployment must cut the interactive
    // first-token tail and serve decode tokens at least as fast.
    let disagg_cfg = numa_attn::coordinator::disagg_scenarios(quick)
        .into_iter()
        .find(|s| s.label == disagg_label)
        .expect("1p+1d scenario in the sweep")
        .cfg;
    let colo1_cfg =
        DisaggConfig { prefill_devices: 0, decode_devices: 1, ..disagg_cfg.clone() };

    let mut colo1 = None;
    h.run("disagg: colocated x1 baseline (SHF)", 3, || {
        colo1 =
            Some(serve_decode_disagg_with(&driver, &topo, &colo1_cfg, Policy::SwizzledHeadFirst));
    });
    let colo1 = colo1.expect("baseline ran");
    h.metric("ttft_p99_ms", colo1.serve.ttft_p99_ms);
    h.metric("tokens_per_sec", colo1.serve.tokens_per_sec);

    let mut split = None;
    h.run("disagg: 1p+1d (SHF)", 3, || {
        split =
            Some(serve_decode_disagg_with(&driver, &topo, &disagg_cfg, Policy::SwizzledHeadFirst));
    });
    let split = split.expect("split run ran");
    let extras = split.extras.as_ref().expect("split run has extras");
    h.metric("interactive_ttft_p99_ms", extras.interactive.ttft_p99_ms);
    h.metric("tokens_per_sec", split.serve.tokens_per_sec);
    h.metric(
        "ttft_speedup_vs_colocated",
        colo1.serve.ttft_p99_ms / extras.interactive.ttft_p99_ms,
    );
    h.metric(
        "tokens_ratio_vs_colocated",
        split.serve.tokens_per_sec / colo1.serve.tokens_per_sec,
    );
    h.metric("handoff_transferred_mb", extras.handoff_transferred_bytes as f64 / (1 << 20) as f64);
    h.metric("preemptions", extras.preemptions as f64);

    let mut split_nhf = None;
    h.run("disagg: 1p+1d (NHF)", 3, || {
        split_nhf =
            Some(serve_decode_disagg_with(&driver, &topo, &disagg_cfg, Policy::NaiveHeadFirst));
    });
    let split_nhf = split_nhf.expect("NHF split run ran");
    let nhf_extras = split_nhf.extras.as_ref().expect("split run has extras");
    h.metric("interactive_ttft_p99_ms", nhf_extras.interactive.ttft_p99_ms);
    h.metric("tokens_per_sec", split_nhf.serve.tokens_per_sec);

    common::check(
        split.serve.tokens == colo1.serve.tokens,
        &format!("identical trace, identical decode tokens ({})", split.serve.tokens),
    );
    common::check(
        extras.interactive.ttft_p99_ms < colo1.serve.ttft_p99_ms,
        &format!(
            "disagg interactive TTFT p99 ({:.3} ms) < colocated x1 TTFT p99 ({:.3} ms)",
            extras.interactive.ttft_p99_ms, colo1.serve.ttft_p99_ms
        ),
    );
    common::check(
        split.serve.tokens_per_sec >= colo1.serve.tokens_per_sec,
        &format!(
            "disagg throughput ({:.0} tok/s) >= colocated x1 ({:.0} tok/s)",
            split.serve.tokens_per_sec, colo1.serve.tokens_per_sec
        ),
    );
    common::check(
        extras.handoff_transferred_bytes > 0,
        &format!(
            "handoffs are priced: {:.1} MB crossed the interconnect in {:.3} ms",
            extras.handoff_transferred_bytes as f64 / (1 << 20) as f64,
            extras.handoff_sec * 1e3
        ),
    );
    common::check(
        extras.preemptions > 0,
        &format!("the 40 ms TTFT objective exercised preemption ({}x)", extras.preemptions),
    );

    // Equal-hardware trade-off, reported (no hard ordering claim).
    let colo2 = pick(colo2_label, Policy::SwizzledHeadFirst);
    println!(
        "[perf] equal hardware: disagg 1p+1d interactive TTFT p99 {:.3} ms @ {:.0} tok/s \
         vs colocated x2 overall TTFT p99 {:.3} ms @ {:.0} tok/s",
        extras.interactive.ttft_p99_ms,
        split.serve.tokens_per_sec,
        colo2.serve.ttft_p99_ms,
        colo2.serve.tokens_per_sec
    );

    let cstats = driver.cache().counters();
    common::check(
        cstats.hits > cstats.misses,
        &format!(
            "the disagg loop re-uses the report cache (hits {} > misses {})",
            cstats.hits, cstats.misses
        ),
    );
    println!(
        "[bench] disagg: {} scenario row(s) in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        fig.rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        cstats.hits,
        cstats.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full sweep" } else { "full sweep" }
    );

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_disagg.json");
    h.write_json(&path).expect("write BENCH_disagg.json");
    println!("[perf] trajectory written to {}", path.display());
}
