//! Paper Fig. 14: Grouped Query Attention (8 KV heads — Llama-3
//! 8B/70B/405B) performance relative to Swizzled Head-first.
//!
//! Reproduction targets:
//! * both swizzled approaches achieve similar performance (the 8 KV
//!   groups match the 8 XCDs, so Swizzled Block-first co-locates too);
//! * Naive Block-first degrades substantially at higher query head
//!   counts and longer sequences.

mod common;

use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let fig = common::run_figure("fig14", figures::fig14);

    let extreme = "llama3-405b H_Q=128 N=128K B=8";
    let sbf = fig.value(extreme, Policy::SwizzledBlockFirst).unwrap();
    let nbf = fig.value(extreme, Policy::NaiveBlockFirst).unwrap();
    common::check(
        sbf > 0.95,
        &format!("Swizzled Block-first matches SHF on GQA with 8 KV heads ({sbf:.3})"),
    );
    common::check(
        nbf < 0.9,
        &format!("Naive Block-first degrades on GQA at scale ({nbf:.3})"),
    );

    let small = "llama3-8b H_Q=32 N=8K B=1";
    let nbf_small = fig.value(small, Policy::NaiveBlockFirst).unwrap();
    common::check(
        nbf_small > 0.85,
        &format!("H_Q=32 keeps policies comparable ({nbf_small:.3})"),
    );
}
