//! Split-KV decode figure (beyond the paper: the serving regime the
//! ROADMAP targets): aggregate L2 hit rates of the two-phase
//! flash-decode pass on the GQA-8 sweep.
//!
//! Reproduction targets:
//! * Swizzled Head-first's hit rate is >= Naive Head-first's on every
//!   row — NHF replicates each (kv head, split) stream across XCDs when
//!   the split count does not divide into the round-robin;
//! * the gap widens with batch (more concurrent streams per L2).

mod common;

use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let fig = common::run_figure("decode", figures::decode_fig);

    for row in &fig.rows {
        let shf = fig.value(&row.label, Policy::SwizzledHeadFirst).unwrap();
        let nhf = fig.value(&row.label, Policy::NaiveHeadFirst).unwrap();
        common::check(
            shf >= nhf,
            &format!("{}: SHF ({shf:.1}%) >= NHF ({nhf:.1}%)", row.label),
        );
    }

    let label = "llama3-70b B=8 N=64K S=4";
    let shf = fig.value(label, Policy::SwizzledHeadFirst).unwrap();
    let nhf = fig.value(label, Policy::NaiveHeadFirst).unwrap();
    common::check(
        shf > nhf,
        &format!("batched decode separates the policies (SHF {shf:.1}% vs NHF {nhf:.1}%)"),
    );
    common::check(shf > 50.0, &format!("SHF keeps a useful hit rate at B=8/64K/S=4 ({shf:.1}%)"));
}
