//! Shared bench scaffolding: every `figN` bench regenerates its paper
//! figure on the MI300X topology, prints the same rows the paper plots,
//! asserts the headline *shape* claims, and reports generation time.
//!
//! `NUMA_ATTN_FULL=1 cargo bench` runs the full paper grids; the default
//! is the quick subset (the extreme + a small corner of each sweep).

use numa_attn::figures::FigureResult;
use numa_attn::topology::{presets, Topology};

pub fn topo() -> Topology {
    presets::mi300x()
}

pub fn full_sweep() -> bool {
    std::env::var("NUMA_ATTN_FULL").is_ok_and(|v| v == "1")
}

/// Render the regenerated figure and time the regeneration.
pub fn run_figure(name: &str, f: impl Fn(&Topology, bool) -> FigureResult) -> FigureResult {
    let topo = topo();
    let quick = !full_sweep();
    let t0 = std::time::Instant::now();
    let fig = f(&topo, quick);
    let dt = t0.elapsed();
    println!("{}", fig.render());
    println!(
        "[bench] {name}: regenerated {} rows in {:.2} s ({})",
        fig.rows.len(),
        dt.as_secs_f64(),
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full grid" } else { "full paper grid" }
    );
    fig
}

/// Assert with a paper-shaped message instead of a panic wall.
pub fn check(cond: bool, what: &str) {
    if cond {
        println!("[check] PASS: {what}");
    } else {
        println!("[check] FAIL: {what}");
        std::process::exit(1);
    }
}
