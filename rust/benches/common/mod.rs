//! Shared bench scaffolding: every `figN` bench regenerates its paper
//! figure on the MI300X topology, prints the same rows the paper plots,
//! asserts the headline *shape* claims, and reports generation time.
//! All figure regeneration executes through the shared simulation driver
//! (`numa_attn::driver`): the sweep fans out across worker threads and
//! repeated jobs hit the memoizing report cache.
//!
//! `NUMA_ATTN_FULL=1 cargo bench` runs the full paper grids; the default
//! is the quick subset (the extreme + a small corner of each sweep).
//! `NUMA_ATTN_THREADS=N` overrides the worker count (default: all cores).

// Each bench is its own crate and uses a subset of these helpers.
#![allow(dead_code)]

use numa_attn::driver::{self, SimDriver};
use numa_attn::figures::FigureResult;
use numa_attn::topology::{presets, Topology};

pub fn topo() -> Topology {
    presets::mi300x()
}

pub fn full_sweep() -> bool {
    std::env::var("NUMA_ATTN_FULL").is_ok_and(|v| v == "1")
}

/// Driver for bench sweeps: all cores unless `NUMA_ATTN_THREADS` says
/// otherwise.
pub fn bench_driver() -> SimDriver {
    let threads = std::env::var("NUMA_ATTN_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(driver::default_threads);
    SimDriver::new(threads)
}

/// Render the regenerated figure and time the regeneration.
pub fn run_figure(
    name: &str,
    f: impl Fn(&SimDriver, &Topology, bool) -> FigureResult,
) -> FigureResult {
    let topo = topo();
    let quick = !full_sweep();
    let driver = bench_driver();
    let t0 = std::time::Instant::now();
    let fig = f(&driver, &topo, quick);
    let dt = t0.elapsed();
    println!("{}", fig.render());
    let cache = driver.cache().counters();
    println!(
        "[bench] {name}: regenerated {} rows in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        fig.rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        cache.hits,
        cache.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full grid" } else { "full paper grid" }
    );
    fig
}

/// Assert with a paper-shaped message instead of a panic wall.
pub fn check(cond: bool, what: &str) {
    if cond {
        println!("[check] PASS: {what}");
    } else {
        println!("[check] FAIL: {what}");
        std::process::exit(1);
    }
}
