//! Paper Fig. 16 / Sec. 4.6: FlashAttention2 BACKWARD pass (dK/dV + dQ
//! kernels) with H_Q = 128, speedup of each mapping over Naive
//! Block-first across 8K-128K context.
//!
//! Reproduction targets:
//! * Swizzled Head-first consistently >= the other mappings;
//! * the speedup is MODEST (paper: ~1.10x at 128K) because the backward
//!   pass's extra scalar work makes it less memory-bound.

mod common;

use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let fig = common::run_figure("fig16", figures::fig16);

    let extreme = "N=128K B=1";
    let shf = fig.value(extreme, Policy::SwizzledHeadFirst).unwrap();
    let nbf = fig.value(extreme, Policy::NaiveBlockFirst).unwrap();
    common::check((nbf - 1.0).abs() < 1e-9, "NBF is the Fig. 16 baseline");
    common::check(
        shf >= 1.0,
        &format!("SHF speeds up the backward pass ({shf:.3}x)"),
    );
    common::check(
        shf < 1.4,
        &format!("backward gains are modest, as in the paper ({shf:.3}x < 1.4x)"),
    );
}
