//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * dispatch chunk size (the driver detail the paper warns "is subject
//!   to change across GPU generations"): a swizzle designed for chunk=1
//!   degrades when the hardware batches dispatch differently;
//! * L2 capacity per XCD (when does SHF's advantage appear?);
//! * number of XCDs (Fig. 1's architecture evolution: unified -> dual
//!   -> quad -> MI300X-style octo);
//! * prefetch depth (double buffering) and launch stagger.

mod common;

use numa_attn::attn::AttnConfig;
use numa_attn::mapping::Policy;
use numa_attn::metrics::Table;
use numa_attn::sim::{simulate, SimConfig};
use numa_attn::topology::presets;

fn main() {
    let base_cfg = AttnConfig::mha(2, 64, 32768, 128);

    // --- chunk size ablation -------------------------------------------
    let mut t = Table::new(&["dispatch chunk", "SHF hit %", "SHF rel perf vs chunk=1"]);
    let mut base_time = None;
    for chunk in [1usize, 2, 4, 8] {
        let mut topo = presets::mi300x();
        topo.dispatch_chunk = chunk;
        let r = simulate(&topo, &base_cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2));
        let b = *base_time.get_or_insert(r.est_total_sec);
        t.row(vec![
            chunk.to_string(),
            format!("{:.1}", r.l2_hit_pct()),
            format!("{:.3}", b / r.est_total_sec),
        ]);
    }
    println!("== ablation: dispatch chunk size (swizzle assumes chunk=1) ==\n{}", t.render());

    // --- L2 capacity ablation ------------------------------------------
    let mut t = Table::new(&["L2/XCD", "SHF hit %", "NBF hit %", "SHF/NBF speedup"]);
    for mb in [1u64, 2, 4, 8, 16] {
        let mut topo = presets::mi300x();
        topo.l2_bytes_per_xcd = mb * 1024 * 1024;
        let shf = simulate(&topo, &base_cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2));
        let nbf = simulate(&topo, &base_cfg, &SimConfig::sampled(Policy::NaiveBlockFirst, &topo, 2));
        t.row(vec![
            format!("{mb} MiB"),
            format!("{:.1}", shf.l2_hit_pct()),
            format!("{:.1}", nbf.l2_hit_pct()),
            format!("{:.3}", nbf.est_total_sec / shf.est_total_sec),
        ]);
    }
    println!("== ablation: L2 capacity per XCD ==\n{}", t.render());

    // --- XCD count (Fig. 1 evolution) -----------------------------------
    let mut t = Table::new(&["topology", "XCDs", "SHF/NBF speedup", "NBF hit %"]);
    for topo in [
        presets::unified_single_die(),
        presets::dual_die(),
        presets::quad_die(),
        presets::mi300x(),
    ] {
        let shf = simulate(&topo, &base_cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2));
        let nbf = simulate(&topo, &base_cfg, &SimConfig::sampled(Policy::NaiveBlockFirst, &topo, 2));
        t.row(vec![
            topo.name.clone(),
            topo.num_xcds.to_string(),
            format!("{:.3}", nbf.est_total_sec / shf.est_total_sec),
            format!("{:.1}", nbf.l2_hit_pct()),
        ]);
    }
    println!("== ablation: disaggregation level (paper Fig. 1) ==\n{}", t.render());

    // --- prefetch depth / launch stagger --------------------------------
    let topo = presets::mi300x();
    let mut t = Table::new(&["prefetch", "stagger", "SHF hit %", "NBF hit %"]);
    for (pf, st) in [(0u32, 20u64), (1, 20), (2, 20), (1, 0), (1, 60)] {
        let mk = |p| SimConfig {
            prefetch_depth: pf,
            launch_stagger: st,
            ..SimConfig::sampled(p, &topo, 2)
        };
        let shf = simulate(&topo, &base_cfg, &mk(Policy::SwizzledHeadFirst));
        let nbf = simulate(&topo, &base_cfg, &mk(Policy::NaiveBlockFirst));
        t.row(vec![
            pf.to_string(),
            st.to_string(),
            format!("{:.1}", shf.l2_hit_pct()),
            format!("{:.1}", nbf.l2_hit_pct()),
        ]);
    }
    println!("== ablation: double buffering & launch stagger ==\n{}", t.render());

    common::check(true, "ablation sweep completed");
}
