//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * dispatch chunk size (the driver detail the paper warns "is subject
//!   to change across GPU generations"): a swizzle designed for chunk=1
//!   degrades when the hardware batches dispatch differently;
//! * L2 capacity per XCD (when does SHF's advantage appear?);
//! * number of XCDs (Fig. 1's architecture evolution: unified -> dual
//!   -> quad -> MI300X-style octo);
//! * prefetch depth (double buffering) and launch stagger.
//!
//! Every sweep is declared as a flat job list and submitted to the shared
//! simulation driver, so the ablation grid fans out across all cores.

mod common;

use numa_attn::attn::AttnConfig;
use numa_attn::driver::SimJob;
use numa_attn::mapping::Policy;
use numa_attn::metrics::Table;
use numa_attn::sim::SimConfig;
use numa_attn::topology::presets;

fn main() {
    let base_cfg = AttnConfig::mha(2, 64, 32768, 128);
    let driver = common::bench_driver();
    let t0 = std::time::Instant::now();

    // --- chunk size ablation -------------------------------------------
    let chunks = [1usize, 2, 4, 8];
    let jobs: Vec<SimJob> = chunks
        .iter()
        .map(|&chunk| {
            let mut topo = presets::mi300x();
            topo.dispatch_chunk = chunk;
            let sc = SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2);
            SimJob::forward(&topo, &base_cfg, sc)
        })
        .collect();
    let reports = driver.run_all(jobs);
    let mut t = Table::new(&["dispatch chunk", "SHF hit %", "SHF rel perf vs chunk=1"]);
    let base_time = reports[0].est_total_sec;
    for (chunk, r) in chunks.iter().zip(&reports) {
        t.row(vec![
            chunk.to_string(),
            format!("{:.1}", r.l2_hit_pct()),
            format!("{:.3}", base_time / r.est_total_sec),
        ]);
    }
    println!("== ablation: dispatch chunk size (swizzle assumes chunk=1) ==\n{}", t.render());

    // --- L2 capacity ablation ------------------------------------------
    let l2_mibs = [1u64, 2, 4, 8, 16];
    let jobs: Vec<SimJob> = l2_mibs
        .iter()
        .flat_map(|&mb| {
            let mut topo = presets::mi300x();
            topo.l2_bytes_per_xcd = mb * 1024 * 1024;
            [Policy::SwizzledHeadFirst, Policy::NaiveBlockFirst].map(|p| {
                SimJob::forward(&topo, &base_cfg, SimConfig::sampled(p, &topo, 2))
            })
        })
        .collect();
    let reports = driver.run_all(jobs);
    let mut t = Table::new(&["L2/XCD", "SHF hit %", "NBF hit %", "SHF/NBF speedup"]);
    for (mb, pair) in l2_mibs.iter().zip(reports.chunks(2)) {
        let (shf, nbf) = (&pair[0], &pair[1]);
        t.row(vec![
            format!("{mb} MiB"),
            format!("{:.1}", shf.l2_hit_pct()),
            format!("{:.1}", nbf.l2_hit_pct()),
            format!("{:.3}", nbf.est_total_sec / shf.est_total_sec),
        ]);
    }
    println!("== ablation: L2 capacity per XCD ==\n{}", t.render());

    // --- XCD count (Fig. 1 evolution) -----------------------------------
    let topos = [
        presets::unified_single_die(),
        presets::dual_die(),
        presets::quad_die(),
        presets::mi300x(),
    ];
    let jobs: Vec<SimJob> = topos
        .iter()
        .flat_map(|topo| {
            [Policy::SwizzledHeadFirst, Policy::NaiveBlockFirst].map(|p| {
                SimJob::forward(topo, &base_cfg, SimConfig::sampled(p, topo, 2))
            })
        })
        .collect();
    let reports = driver.run_all(jobs);
    let mut t = Table::new(&["topology", "XCDs", "SHF/NBF speedup", "NBF hit %"]);
    for (topo, pair) in topos.iter().zip(reports.chunks(2)) {
        let (shf, nbf) = (&pair[0], &pair[1]);
        t.row(vec![
            topo.name.clone(),
            topo.num_xcds.to_string(),
            format!("{:.3}", nbf.est_total_sec / shf.est_total_sec),
            format!("{:.1}", nbf.l2_hit_pct()),
        ]);
    }
    println!("== ablation: disaggregation level (paper Fig. 1) ==\n{}", t.render());

    // --- prefetch depth / launch stagger --------------------------------
    let topo = presets::mi300x();
    let knobs = [(0u32, 20u64), (1, 20), (2, 20), (1, 0), (1, 60)];
    let jobs: Vec<SimJob> = knobs
        .iter()
        .flat_map(|&(pf, st)| {
            [Policy::SwizzledHeadFirst, Policy::NaiveBlockFirst].map(|p| {
                let sc = SimConfig {
                    prefetch_depth: pf,
                    launch_stagger: st,
                    ..SimConfig::sampled(p, &topo, 2)
                };
                SimJob::forward(&topo, &base_cfg, sc)
            })
        })
        .collect();
    let reports = driver.run_all(jobs);
    let mut t = Table::new(&["prefetch", "stagger", "SHF hit %", "NBF hit %"]);
    for ((pf, st), pair) in knobs.iter().zip(reports.chunks(2)) {
        t.row(vec![
            pf.to_string(),
            st.to_string(),
            format!("{:.1}", pair[0].l2_hit_pct()),
            format!("{:.1}", pair[1].l2_hit_pct()),
        ]);
    }
    println!("== ablation: double buffering & launch stagger ==\n{}", t.render());

    let cache = driver.cache().counters();
    println!(
        "[bench] ablations: {} engine run(s) on {} thread(s) in {:.2} s",
        cache.misses,
        driver.threads(),
        t0.elapsed().as_secs_f64()
    );
    common::check(true, "ablation sweep completed");
}
