//! Paper Fig. 12: MHA performance relative to Swizzled Head-first across
//! batch sizes (1-8) and sequence lengths (8K-128K).
//!
//! Reproduction targets (shape, not absolute numbers):
//! * all policies comparable at small head counts;
//! * block-first degrades as heads/sequence/batch grow;
//! * at H_Q=128, N_CTX=128K the gap reaches ~1.5x ("up to 50% higher").

mod common;

use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let fig = common::run_figure("fig12", figures::fig12);

    let extreme = "H=128 N=128K B=8";
    let nbf = fig.value(extreme, Policy::NaiveBlockFirst).unwrap();
    let sbf = fig.value(extreme, Policy::SwizzledBlockFirst).unwrap();
    let shf = fig.value(extreme, Policy::SwizzledHeadFirst).unwrap();
    common::check((shf - 1.0).abs() < 1e-9, "SHF is the normalization baseline");
    common::check(
        nbf < 0.75 && sbf < 0.75,
        &format!("block-first loses >=25% at the extreme config (NBF {nbf:.3}, SBF {sbf:.3})"),
    );
    common::check(
        1.0 / nbf >= 1.3,
        &format!("SHF speedup over block-first reaches paper scale ({:.2}x)", 1.0 / nbf),
    );

    let small = "H=8 N=8K B=1";
    let nbf_small = fig.value(small, Policy::NaiveBlockFirst).unwrap();
    common::check(
        nbf_small > 0.9,
        &format!("small configs perform similarly across policies (NBF {nbf_small:.3})"),
    );
}
