//! Serving-coordinator benchmarks: end-to-end latency/throughput of the
//! router + batcher + PJRT execution path on the AOT artifacts, plus the
//! batcher/router micro-costs (the L3 §Perf target: batcher overhead
//! << PJRT execute time).
//!
//! Requires `make artifacts` to have produced `artifacts/`.

mod common;

use std::time::{Duration, Instant};

use numa_attn::coordinator::{AttentionService, BatcherConfig, BatcherCore, Router, ServiceConfig};
use numa_attn::runtime::Manifest;
use numa_attn::util::bench::Harness;
use numa_attn::workload::{Request, RequestGenerator};

fn main() {
    let artifact_dir = std::path::Path::new("artifacts");
    if !artifact_dir.join("manifest.json").exists() {
        println!("[bench] coordinator: artifacts/ not built (run `make artifacts`); skipping");
        return;
    }
    let mut h = Harness::new("coordinator");

    // --- micro: router + batcher ----------------------------------------
    let manifest = Manifest::load(artifact_dir).unwrap();
    let router = Router::from_manifest(&manifest);
    let mut gen = RequestGenerator::new(3, router.bucket_lengths());
    let reqs: Vec<Request> = gen.take(10_000);
    h.run("router: 10k routes", 20, || {
        let mut n = 0usize;
        for r in &reqs {
            if router.route(r).is_ok() {
                n += 1;
            }
        }
        std::hint::black_box(n);
    });

    h.run("batcher: 10k push/release", 20, || {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) });
        let t = Instant::now();
        let mut released = 0usize;
        for r in &reqs {
            let name = router.route(r).unwrap();
            if let Some(batch) = b.push(name, r.clone(), t) {
                released += batch.requests.len();
            }
        }
        std::hint::black_box(released);
    });

    // --- end-to-end service ----------------------------------------------
    let service = AttentionService::start(ServiceConfig {
        artifact_dir: artifact_dir.to_path_buf(),
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
    })
    .expect("service start");
    let lengths = service.router().bucket_lengths();
    let mut gen = RequestGenerator::new(7, lengths);

    for batch_requests in [8usize, 32] {
        let reqs = gen.take(batch_requests);
        let t0 = Instant::now();
        let waiters: Vec<_> = reqs
            .into_iter()
            .map(|r| service.submit(r).unwrap())
            .collect();
        let ok = waiters.into_iter().filter(|_| true).map(|w| w.wait()).filter(Result::is_ok).count();
        let dt = t0.elapsed();
        println!(
            "[bench] serve {batch_requests} reqs: {:.1} ms total, {:.2} ms/req, {:.1} req/s ({ok} ok)",
            dt.as_secs_f64() * 1e3,
            dt.as_secs_f64() * 1e3 / batch_requests as f64,
            batch_requests as f64 / dt.as_secs_f64()
        );
    }
    let m = service.shutdown();
    println!(
        "[bench] service metrics: {} reqs, {} batches, {} stacked, queue p99 {} us, exec mean {:.0} us",
        m.requests, m.batches, m.stacked_executions, m.queue_wait.p99_us, m.exec.mean_us
    );
    common::check(m.errors == 0, "no serving errors");
}
