//! Tensor-parallel cluster serving bench (docs/CLUSTER.md): runs the
//! cluster sweep on real MI300X devices and asserts the two-level NUMA
//! claims end to end.
//!
//! Reproduction targets:
//! * SwizzledHeadFirst's decode tokens/s >= NaiveHeadFirst's on every
//!   (scenario, TP) row — the level-2 mapping win survives head sharding;
//! * SHF's decode L2 hit rate >= NHF's on every row, and on the raw
//!   per-shard decode grids at the TP extremes;
//! * TP-8 serves tokens at least as fast as TP-1 (sharding pays for its
//!   all-gather) on every scenario, under SHF;
//! * identical shards collapse in the report cache (hits > misses).

mod common;

use numa_attn::coordinator::serve_cluster_report;
use numa_attn::driver::SimJob;
use numa_attn::mapping::Policy;
use numa_attn::sim::SimConfig;
use numa_attn::workload::sweeps;

fn main() {
    let driver = common::bench_driver();
    let topo = common::topo();
    let quick = !common::full_sweep();

    let t0 = std::time::Instant::now();
    let report = serve_cluster_report(&driver, &topo, quick);
    let dt = t0.elapsed();
    print!("{}", report.render());

    // Per-row policy ordering: throughput AND decode locality.
    for row in &report.rows {
        let shf = report.stats(&row.label, Policy::SwizzledHeadFirst).unwrap();
        let nhf = report.stats(&row.label, Policy::NaiveHeadFirst).unwrap();
        common::check(
            shf.tokens_per_sec >= nhf.tokens_per_sec,
            &format!(
                "{}: SHF ({:.0} tok/s) >= NHF ({:.0} tok/s)",
                row.label, shf.tokens_per_sec, nhf.tokens_per_sec
            ),
        );
        common::check(
            shf.decode_l2_hit_pct >= nhf.decode_l2_hit_pct,
            &format!(
                "{}: SHF decode L2 ({:.1}%) >= NHF ({:.1}%)",
                row.label, shf.decode_l2_hit_pct, nhf.decode_l2_hit_pct
            ),
        );
        common::check(shf.tokens_per_sec > 0.0, &format!("{}: non-degenerate", row.label));
    }

    // TP scaling: the widest shard must at least match the narrowest on
    // every scenario (the all-gather tax never eats the whole win). The
    // endpoints come from the sweep axis itself, so extending CLUSTER_TP
    // moves this check to the new extremes automatically.
    let (tp_min, tp_max) = (sweeps::CLUSTER_TP[0], *sweeps::CLUSTER_TP.last().unwrap());
    let bases: Vec<String> = {
        let mut b: Vec<String> = report.rows.iter().map(|r| r.base.clone()).collect();
        b.dedup();
        b
    };
    for base in &bases {
        let lo = report.rows.iter().find(|r| r.base == *base && r.tp == tp_min).unwrap();
        let hi = report.rows.iter().find(|r| r.base == *base && r.tp == tp_max).unwrap();
        let s_lo = report.stats(&lo.label, Policy::SwizzledHeadFirst).unwrap();
        let s_hi = report.stats(&hi.label, Policy::SwizzledHeadFirst).unwrap();
        common::check(
            s_hi.tokens_per_sec >= s_lo.tokens_per_sec,
            &format!(
                "{base}: TP-{tp_max} ({:.0} tok/s) >= TP-{tp_min} ({:.0} tok/s)",
                s_hi.tokens_per_sec, s_lo.tokens_per_sec
            ),
        );
        let eff = report.efficiency(hi, Policy::SwizzledHeadFirst).unwrap();
        println!("[bench] {base}: TP-{tp_max} scaling efficiency {eff:.2} vs ideal");
    }

    // Level-2 locality on the raw per-shard decode grids: the sharded
    // GQA-8 sweep must keep SHF's L2 hit rate at or above NHF's at both
    // TP extremes (split counts deliberately not XCD multiples).
    for tp in [tp_min, tp_max] {
        let n_ctxs = [16 * 1024, 64 * 1024];
        let pts = sweeps::sharded_gqa8_decode_sweep(tp, &n_ctxs, &[1, 8], &sweeps::DECODE_SPLITS);
        for pt in &pts {
            let run = |p: Policy| {
                driver.run(SimJob::decode(&topo, &pt.cfg, SimConfig::decode(p, pt.num_splits)))
            };
            let shf = run(Policy::SwizzledHeadFirst);
            let nhf = run(Policy::NaiveHeadFirst);
            common::check(
                shf.l2_hit_pct() >= nhf.l2_hit_pct(),
                &format!(
                    "{}: shard SHF L2 ({:.1}%) >= NHF ({:.1}%)",
                    pt.label,
                    shf.l2_hit_pct(),
                    nhf.l2_hit_pct()
                ),
            );
        }
    }

    let c = driver.cache().counters();
    common::check(
        c.hits > c.misses,
        &format!("identical shards collapse in the cache (hits {} > misses {})", c.hits, c.misses),
    );
    println!(
        "[bench] cluster_scaling: {} row(s) in {:.2} s on {} thread(s), \
         cache {} hit(s)/{} miss(es) ({})",
        report.rows.len(),
        dt.as_secs_f64(),
        driver.threads(),
        c.hits,
        c.misses,
        if quick { "quick sweep; NUMA_ATTN_FULL=1 for the full TP axis" } else { "full sweep" }
    );
}
