//! Paper Fig. 15 / Sec. 4.5 case study: DeepSeek-V3 prefill — MHA with
//! 128 query AND 128 KV heads, D_HEAD = 56 — across 2K-128K context and
//! batch 1-8, relative to Swizzled Head-first.
//!
//! Reproduction targets:
//! * SHF is superior across configurations, especially at long context;
//! * Naive Block-first is worst at 128K;
//! * the smaller head dimension lowers ABSOLUTE performance for every
//!   method (checked via the achieved-TFLOP/s of a direct sim run).

mod common;

use numa_attn::attn::AttnConfig;
use numa_attn::figures;
use numa_attn::mapping::Policy;
use numa_attn::sim::{simulate, SimConfig};

fn main() {
    let fig = common::run_figure("fig15", figures::fig15);

    let extreme = "N=128K B=8";
    let nbf = fig.value(extreme, Policy::NaiveBlockFirst).unwrap();
    let shf = fig.value(extreme, Policy::SwizzledHeadFirst).unwrap();
    common::check((shf - 1.0).abs() < 1e-9, "SHF is the baseline");
    common::check(
        nbf < 0.95,
        &format!("Naive Block-first is worst at 128K ({nbf:.3})"),
    );

    // D_HEAD=56 lowers absolute performance vs D=128 at the same shape.
    let topo = common::topo();
    let sc = SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2);
    let d56 = simulate(&topo, &AttnConfig::mha(1, 128, 32768, 56), &sc);
    let d128 = simulate(&topo, &AttnConfig::mha(1, 128, 32768, 128), &sc);
    common::check(
        d56.achieved_tflops < d128.achieved_tflops,
        &format!(
            "D=56 lowers absolute performance ({:.0} vs {:.0} TFLOP/s)",
            d56.achieved_tflops, d128.achieved_tflops
        ),
    );
}
