//! Simulator hot-path micro-benchmarks: tile-access throughput of the
//! engine, LRU cache ops, and mapping decode — the §Perf targets for
//! Layer 3 (DESIGN.md: the Table-2 sweep must run in minutes, so the
//! engine needs >~10M tile-accesses/s/core).

mod common;

use numa_attn::attn::{AttnConfig, KernelKind};
use numa_attn::cache::LruCache;
use numa_attn::mapping::{Mapping, Policy};
use numa_attn::sim::{simulate, SimConfig};
use numa_attn::util::bench::Harness;

fn main() {
    let mut h = Harness::new("sim_hotpath");
    let topo = common::topo();

    // End-to-end engine throughput on a paper-scale sampled config.
    let cfg = AttnConfig::mha(1, 64, 32768, 128);
    let mut accesses = 0u64;
    h.run("engine: H=64 N=32K sampled (SHF)", 5, || {
        let r = simulate(&topo, &cfg, &SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2));
        accesses = r.l2.accesses();
    });
    let per_iter = h.results().last().unwrap().mean.as_secs_f64();
    println!(
        "[perf] engine throughput: {:.1}M demand accesses/s ({} accesses/iter)",
        accesses as f64 / per_iter / 1e6,
        accesses
    );

    // Worst-case policy (block-first thrash floods the HBM queue).
    h.run("engine: H=64 N=32K sampled (NBF)", 5, || {
        let _ = simulate(&topo, &cfg, &SimConfig::sampled(Policy::NaiveBlockFirst, &topo, 2));
    });

    // Backward both-kernel run.
    let bwd_cfg = AttnConfig::mha(1, 128, 8192, 128);
    h.run("engine: backward H=128 N=8K", 3, || {
        let _ = numa_attn::sim::simulate_backward(
            &topo,
            &bwd_cfg,
            &SimConfig::backward(Policy::SwizzledHeadFirst),
        );
    });

    // LRU cache ops.
    h.run("lru: 1M mixed accesses, 25% working-set overflow", 10, || {
        let mut c = LruCache::new(256 * 16 * 1024);
        for i in 0..1_000_000u64 {
            c.access(i % 320, 16 * 1024);
        }
        std::hint::black_box(c.stats().hits);
    });

    // Mapping decode (the per-dispatch O(1) path).
    let m = Mapping::for_kernel(
        Policy::SwizzledHeadFirst,
        &AttnConfig::mha(8, 128, 131072, 128),
        KernelKind::Forward,
        8,
    )
    .unwrap();
    h.run("mapping: 10M swizzled decodes", 10, || {
        let mut acc = 0u64;
        for s in 0..10_000_000usize {
            let w = m.decode(s % m.grid_size());
            acc = acc.wrapping_add(w.h as u64);
        }
        std::hint::black_box(acc);
    });
}
