//! Simulator hot-path micro-benchmarks: tile-access throughput of the
//! engine, LRU cache ops, and mapping decode — the §Perf targets for
//! Layer 3 (DESIGN.md: the Table-2 sweep must run in minutes, so the
//! engine needs >~10M tile-accesses/s/core).
//!
//! Besides the console rows, this bench writes the pinned perf
//! trajectory `BENCH_sim_hotpath.json` at the repo root (docs/PERF.md):
//! per-case mean/min/max plus derived metrics — `accesses_per_sec` for
//! the engine-throughput floor and `speedup_vs_reference` comparing the
//! event-driven engine against the reference per-tick scan on the same
//! workload (`engine-reference:` cases time the oracle directly).

mod common;

use numa_attn::attn::{AttnConfig, KernelKind};
use numa_attn::cache::LruCache;
use numa_attn::mapping::{Mapping, Policy};
use numa_attn::sim::{simulate, simulate_decode, simulate_reference, SimConfig};
use numa_attn::util::bench::Harness;

fn main() {
    let mut h = Harness::new("sim_hotpath");
    let topo = common::topo();

    // End-to-end engine throughput on a paper-scale sampled config. This
    // is the compute-bound regime (slots advance almost every tick), so
    // the event queue buys little here — the case exists to pin the
    // accesses/s floor, not the event-skip win.
    let cfg = AttnConfig::mha(1, 64, 32768, 128);
    let shf = SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2);
    let mut accesses = 0u64;
    h.run("engine: H=64 N=32K sampled (SHF)", 5, || {
        let r = simulate(&topo, &cfg, &shf);
        accesses = r.l2.accesses();
    });
    let fwd_mean = h.results().last().unwrap().mean.as_secs_f64();
    let aps = accesses as f64 / fwd_mean;
    println!(
        "[perf] engine throughput: {:.1}M demand accesses/s ({} accesses/iter)",
        aps / 1e6,
        accesses
    );
    h.metric("accesses_per_sec", aps);

    h.run("engine-reference: H=64 N=32K sampled (SHF)", 3, || {
        let _ = simulate_reference(&topo, &cfg, &shf);
    });
    let fwd_ref_mean = h.results().last().unwrap().mean.as_secs_f64();
    h.metric("speedup_vs_event", fwd_ref_mean / fwd_mean);

    // Worst-case policy (block-first thrash floods the HBM queue).
    h.run("engine: H=64 N=32K sampled (NBF)", 5, || {
        let _ = simulate(&topo, &cfg, &SimConfig::sampled(Policy::NaiveBlockFirst, &topo, 2));
    });

    // Backward both-kernel run.
    let bwd_cfg = AttnConfig::mha(1, 128, 8192, 128);
    h.run("engine: backward H=128 N=8K", 3, || {
        let _ = numa_attn::sim::simulate_backward(
            &topo,
            &bwd_cfg,
            &SimConfig::backward(Policy::SwizzledHeadFirst),
        );
    });

    // Flash-decode, both phases (split-KV + reduction).
    let dec_cfg = AttnConfig::gqa(32, 64, 8, 65536, 128);
    let dec_sim = SimConfig::decode(Policy::SwizzledHeadFirst, 16);
    h.run("engine: decode split16 B=32 GQA-8 N=64K", 3, || {
        let _ = simulate_decode(&topo, &dec_cfg, &dec_sim);
    });

    // The reduction phase alone: the latency-epoch regime the event
    // engine exists for. Its ticks are tiny (step FLOPs are a vector
    // merge), so the HBM latency spans thousands of ticks and the
    // reference engine spends almost all its time scanning slots that
    // cannot move. This is the headline speedup case the acceptance
    // criterion pins (>= 10x vs the pre-PR engine, which is the
    // reference scan).
    let red_sim = SimConfig {
        kernel: KernelKind::DecodeReduce { num_splits: 16 },
        ..dec_sim
    };
    h.run("engine: decode-reduce B=32 H=64 splits=16", 5, || {
        let _ = simulate(&topo, &dec_cfg, &red_sim);
    });
    let red_mean = h.results().last().unwrap().mean.as_secs_f64();

    h.run("engine-reference: decode-reduce B=32 H=64 splits=16", 3, || {
        let _ = simulate_reference(&topo, &dec_cfg, &red_sim);
    });
    let red_ref_mean = h.results().last().unwrap().mean.as_secs_f64();
    println!(
        "[perf] decode-reduce: event {:.3} ms vs reference {:.3} ms ({:.1}x)",
        red_mean * 1e3,
        red_ref_mean * 1e3,
        red_ref_mean / red_mean
    );

    // LRU cache ops.
    h.run("lru: 1M mixed accesses, 25% working-set overflow", 10, || {
        let mut c = LruCache::new(256 * 16 * 1024);
        for i in 0..1_000_000u64 {
            c.access(i % 320, 16 * 1024);
        }
        std::hint::black_box(c.stats().hits);
    });

    // Mapping decode (the per-dispatch O(1) path).
    let m = Mapping::for_kernel(
        Policy::SwizzledHeadFirst,
        &AttnConfig::mha(8, 128, 131072, 128),
        KernelKind::Forward,
        8,
    )
    .unwrap();
    h.run("mapping: 10M swizzled decodes", 10, || {
        let mut acc = 0u64;
        for s in 0..10_000_000usize {
            let w = m.decode(s % m.grid_size());
            acc = acc.wrapping_add(w.h as u64);
        }
        std::hint::black_box(acc);
    });

    // Attach the headline speedup to the decode-reduce EVENT case (found
    // by name so case insertions above cannot silently re-target it),
    // then pin the trajectory at the repo root.
    let idx = h
        .results()
        .iter()
        .position(|r| r.name.starts_with("engine: decode-reduce"))
        .expect("decode-reduce case present");
    h.metric_at(idx, "speedup_vs_reference", red_ref_mean / red_mean);

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_sim_hotpath.json");
    h.write_json(&path).expect("write BENCH_sim_hotpath.json");
    println!("[perf] trajectory written to {}", path.display());
}
