//! Paper Fig. 13: aggregate L2 cache hit rates for the MHA sweep
//! (2K-128K context, 1-8 batch, 8-128 heads).
//!
//! Reproduction targets:
//! * Swizzled Head-first sustains high hit rates (80-97%) everywhere;
//! * block-first approaches collapse toward ~1% at H=128 / 128K;
//! * with few heads / short sequences all approaches are high.

mod common;

use numa_attn::figures;
use numa_attn::mapping::Policy;

fn main() {
    let fig = common::run_figure("fig13", figures::fig13);

    let extreme = "H=128 N=128K B=8";
    let shf = fig.value(extreme, Policy::SwizzledHeadFirst).unwrap();
    let nbf = fig.value(extreme, Policy::NaiveBlockFirst).unwrap();
    common::check(
        shf > 80.0,
        &format!("SHF sustains >80% L2 hit rate at the extreme ({shf:.1}%)"),
    );
    common::check(
        nbf < 20.0,
        &format!("block-first collapses at the extreme ({nbf:.1}%)"),
    );

    let small = "H=8 N=2K B=1";
    let nbf_small = fig.value(small, Policy::NaiveBlockFirst).unwrap();
    let shf_small = fig.value(small, Policy::SwizzledHeadFirst).unwrap();
    common::check(
        nbf_small > 80.0 && shf_small > 80.0,
        &format!("all approaches ~90% at the small corner (NBF {nbf_small:.1}%, SHF {shf_small:.1}%)"),
    );
}
